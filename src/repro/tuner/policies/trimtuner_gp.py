"""TrimTuner's cost-aware acquisition over a Gaussian-process posterior.

``TrimTunerSearcher`` (ridge posterior, grid enumeration) reproduces the
TrimTuner acquisition on the paper's 16-point lattice; this module is the
continuous relaxation the real TrimTuner (Mendes et al., 2020) is defined
over, in the syne-tune idiom (a GP posterior over normalized HP
coordinates — cf. the independent-per-resource GP reference under
``/root/related/aaronkl__syne-tune``, collapsed here to a single fidelity
feature instead of per-resource states):

  * **model** — a Matérn-5/2 GP over the space's encoded ``[0,1]^d``
    features plus a fidelity-deficit column (``1 - steps/max_steps``; the
    sub-sampled bootstrap wave enters at deficit > 0 and predictions are
    made at deficit 0, which de-biases the cheap runs exactly as the ridge
    model's deficit coefficient did).  Fixed lengthscale, empirical mean /
    signal variance, closed-form numpy Cholesky — no hyper-parameter
    optimization loop, so every posterior is a pure deterministic function
    of the (seed, feedback sequence) pair, which the sweep's batched ==
    sequential contract requires.
  * **acquisition** — expected improvement per predicted dollar.  The cost
    model is the same Bayesian ridge over $/step observations TrimTuner
    uses (costs are near-affine in the encoded coords; a GP buys nothing).
  * **optimizer** — seeded random search over the space (``n_candidates``
    draws) plus local search around the incumbents: ``Domain.neighbor``
    perturbations of the best observed configs.  On a finite space the
    candidate set is simply every unexplored grid point, which makes the
    grid the degenerate case rather than a separate code path downstream.

Registered as searcher ``trimtuner-gp``; the ``trimtuner-gp`` *policy* row
in the benchmarks pairs it with ``AdaptiveSpotTuneScheduler`` (θ-budget +
fidelity-gap verification + EarlyCurve phase-2), same as ``adaptive``.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.trial import TrialSpec, Workload
from repro.tuner.policies.trimtuner import _norm_cdf, _norm_pdf, _posterior
from repro.tuner.scheduler import Searcher


def matern52(A: np.ndarray, B: np.ndarray, lengthscale: float) -> np.ndarray:
    """Matérn-5/2 kernel matrix between row sets A (n,d) and B (m,d)."""
    A = np.asarray(A, np.float64) / lengthscale
    B = np.asarray(B, np.float64) / lengthscale
    d2 = np.maximum(
        (A * A).sum(1)[:, None] + (B * B).sum(1)[None, :] - 2.0 * (A @ B.T),
        0.0)
    r = np.sqrt(d2)
    s5 = math.sqrt(5.0) * r
    return (1.0 + s5 + (5.0 / 3.0) * d2) * np.exp(-s5)


class GPPosterior:
    """Exact GP regression posterior, fixed hyper-parameters.

    Empirical mean and signal variance, Matérn-5/2 covariance, Cholesky
    factorization once per fit; ``predict`` returns marginal means and
    variances at test rows.  Deliberately tiny: TrimTuner observes tens of
    points, not thousands, and determinism beats adaptivity here."""

    def __init__(self, X: np.ndarray, y: np.ndarray,
                 lengthscale: float = 0.4, noise_frac: float = 1e-3):
        self.X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        self.ls = lengthscale
        self.mean = float(y.mean())
        var = float(y.var())
        self.sig2 = max(var, 1e-8)
        noise = max(noise_frac * self.sig2, 1e-10)
        K = self.sig2 * matern52(self.X, self.X, self.ls)
        K[np.diag_indices_from(K)] += noise
        self.L = np.linalg.cholesky(K)
        self.alpha = np.linalg.solve(
            self.L.T, np.linalg.solve(self.L, y - self.mean))

    def predict(self, Xs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        Ks = self.sig2 * matern52(np.asarray(Xs, np.float64), self.X, self.ls)
        mu = self.mean + Ks @ self.alpha
        V = np.linalg.solve(self.L, Ks.T)
        var = np.maximum(self.sig2 - np.sum(V * V, axis=0), 1e-12)
        return mu, var


class TrimTunerGPSearcher(Searcher):
    """Cost-aware GP Bayesian optimization over any ``SearchSpace``."""

    live_results = True
    supports_continuous = True

    def __init__(self, workload: Workload, initial: int = 6, batch: int = 3,
                 sub_frac: float = 0.4, max_trials: int = 14,
                 n_candidates: int = 256, n_incumbents: int = 3,
                 n_neighbors: int = 8, lengthscale: float = 0.4,
                 ridge: float = 1e-2, seed: int = 0):
        assert 0.0 < sub_frac <= 1.0
        self.workload = workload
        self.space = workload.space
        self.batch = batch
        self.sub_frac = sub_frac
        self.lengthscale = lengthscale
        self.ridge = ridge
        self.n_candidates = n_candidates
        self.n_incumbents = n_incumbents
        self.n_neighbors = n_neighbors
        self._rng = np.random.default_rng(seed)
        self._grid = self.space.grid() if self.space.is_finite else None
        if self._grid is not None:
            max_trials = min(max_trials, len(self._grid))
        self.max_trials = max_trials
        self._suggested_hashes: set = set()
        self._n_suggested = 0
        # (hp, grid idx or GRID_FREE, budget_frac)
        self._queue: List[Tuple[dict, int, float]] = []
        self._bootstrap(initial)
        # (hp, fidelity in (0,1], metric, billed $, steps)
        self._obs: List[Tuple[dict, float, float, float, float]] = []

    # ----------------------------------------------------------- bootstrap
    def _bootstrap(self, initial: int) -> None:
        """Cheap sub-sampled seed wave: a random design over the space.
        ``sample_distinct`` terminates with a smaller wave when a
        continuous-typed space is effectively tiny."""
        n0 = min(initial, self.max_trials)
        if self._grid is not None:
            order = self._rng.permutation(len(self._grid))
            for i in order[:n0]:
                self._push(self._grid[int(i)], int(i), self.sub_frac)
            return
        for hp in self.space.sample_distinct(self._rng, n0,
                                             seen=self._suggested_hashes):
            self._queue.append((hp, TrialSpec.GRID_FREE, self.sub_frac))

    def _push(self, hp: dict, idx: int, frac: float) -> bool:
        h = self.space.config_hash(hp)
        if h in self._suggested_hashes:
            return False
        self._suggested_hashes.add(h)
        self._queue.append((hp, idx, frac))
        return True

    # ------------------------------------------------------------ protocol
    def suggest(self) -> Optional[TrialSpec]:
        if not self._queue:
            self._refine()
        if not self._queue:
            return None
        hp, idx, frac = self._queue.pop(0)
        self._n_suggested += 1
        return TrialSpec(self.workload, hp, idx, budget_frac=frac)

    def on_trial_finished(self, view) -> None:
        """Rich feedback: final metric + the engine's billed dollars."""
        if not view.metrics_vals:
            return
        fid = min(1.0, view.steps / view.spec.workload.max_trial_steps)
        cost = max(float(getattr(view, "billed_cost", 0.0)), 0.0)
        self._obs.append((view.spec.hp, max(fid, 1e-3),
                          float(view.metrics_vals[-1]), cost,
                          max(float(view.steps), 1.0)))

    # ----------------------------------------------------------- modelling
    def _design(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        F = self.space.encode([o[0] for o in self._obs])
        X = np.column_stack(
            [F, np.array([1.0 - o[1] for o in self._obs])])   # deficit col
        y = np.array([o[2] for o in self._obs])
        cps = np.array([o[3] / o[4] for o in self._obs])      # $ per step
        return F, X, y, cps

    def _candidates(self) -> List[dict]:
        """Acquisition support: unexplored grid (finite) or seeded random +
        incumbent-neighborhood draws (continuous)."""
        if self._grid is not None:
            return [hp for hp in self._grid
                    if self.space.config_hash(hp)
                    not in self._suggested_hashes]
        cands = self.space.sample(self._rng, self.n_candidates)
        best = sorted(self._obs, key=lambda o: o[2])[: self.n_incumbents]
        for hp, *_ in best:                       # local search around them
            for _ in range(self.n_neighbors):
                cands.append(self.space.neighbor(hp, self._rng))
        seen = set(self._suggested_hashes)
        out = []
        for hp in cands:
            h = self.space.config_hash(hp)
            if h not in seen:
                seen.add(h)
                out.append(hp)
        return out

    def _refine(self) -> None:
        if self._n_suggested + len(self._queue) >= self.max_trials \
                or len(self._obs) < 2:
            return
        cand = self._candidates()
        if not cand:
            return
        F, X, y, cps = self._design()
        gp = GPPosterior(X, y, lengthscale=self.lengthscale)
        Fc = self.space.encode(cand)
        # predict at full fidelity: deficit column pinned to 0
        mu, var = gp.predict(np.column_stack([Fc, np.zeros(len(cand))]))
        s = np.sqrt(var)
        best = float(np.min(y))
        gamma = (best - mu) / s
        ei = s * (gamma * _norm_cdf(gamma) + _norm_pdf(gamma))
        # predicted full-budget dollars (ridge over observed $/step, floored
        # so a lucky free run can't absorb the whole batch)
        cmu, _, _ = _posterior(
            np.column_stack([np.ones(len(self._obs)), F]), cps, self.ridge)
        floor = 0.05 * max(float(np.median(cps)), 1e-9)
        c_pred = np.maximum(
            np.column_stack([np.ones(len(cand)), Fc]) @ cmu,
            floor) * self.workload.max_trial_steps
        acq = ei / c_pred
        take = min(self.batch,
                   self.max_trials - self._n_suggested - len(self._queue))
        for j in np.argsort(-acq, kind="stable")[:take]:
            hp = cand[int(j)]
            idx = (self.space.grid_index(hp) if self._grid is not None
                   else TrialSpec.GRID_FREE)
            self._push(hp, idx if idx is not None else TrialSpec.GRID_FREE,
                       1.0)                       # refinement: full budget
