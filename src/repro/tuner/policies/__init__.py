"""Search-policy suite on top of the transient-resource engine.

Every policy here rides the same ``ExecutionEngine`` mechanics (Eq.-2
provisioning, revocation-as-free-pause, first-hour refunds, 1-hour
rotation) through the ``Scheduler``/``Searcher`` protocols:

  hyperband   ``HyperbandScheduler`` — multiple ASHA brackets with
              budget-proportional bracket sampling; revocations still
              count as free rung boundaries inside every bracket
  pbt         ``PBTScheduler`` + ``PBTSearcher`` — population-based
              training: truncation selection at step milestones via
              PAUSE/PROMOTE, exploit/explore replacements (config
              perturb/resample) drawn through the incremental-suggestion
              idle path
  trimtuner   ``TrimTunerSearcher`` — TrimTuner-style cost-aware Bayesian
              optimization (arXiv 2011.04726): sub-sampled cheap trials
              bootstrap the model, acquisition = expected improvement per
              predicted dollar cost
  trimtuner_gp  ``TrimTunerGPSearcher`` — the continuous relaxation:
              Matérn-5/2 GP posterior over ``SearchSpace``-encoded
              features, EI-per-dollar optimized by seeded random + local
              search over the space (finite grids are the degenerate case)

All three implement ``preview_metrics`` so the engine's boundary-jumping
fast path stays event-driven, and all run unmodified under
``repro.sweep.SweepRunner`` (batched == sequential bit-for-bit).  The
name -> factory registry that ties them (and the pre-existing policies)
into sweeps, benchmarks, and the conformance harness lives in
``repro.tuner.registry``.
"""

from repro.tuner.policies.hyperband import HyperbandScheduler  # noqa: F401
from repro.tuner.policies.pbt import PBTScheduler, PBTSearcher  # noqa: F401
from repro.tuner.policies.trimtuner import TrimTunerSearcher  # noqa: F401
from repro.tuner.policies.trimtuner_gp import (GPPosterior,  # noqa: F401
                                               TrimTunerGPSearcher,
                                               matern52)
