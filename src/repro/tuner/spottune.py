"""The paper's search policy, re-expressed as a pluggable Scheduler.

SpotTune's Algorithm 1 policy, extracted from the old monolithic orchestrator
loop and restated against the Scheduler protocol:

  * every trial's initial budget is ``floor(theta * max_trial_steps)``;
  * a trial whose metric plateaus (EarlyCurve's §III-C special case) is
    STOPped early;
  * when the engine drains (phase-1 idle), EarlyCurve extrapolates every
    trial's final metric from its partial trajectory (seeded, so ranking is
    reproducible), and the top-``mcnt`` predicted trials are promoted to the
    full ``max_trial_steps`` budget — in predicted-rank order, which is also
    the redeployment order (this preserves the legacy RNG-draw sequence);
  * the second idle ends the run; the final ranking keeps the *phase-1*
    predictions (the paper reports selection accuracy of the early
    extrapolation, not of the finished winners).

Driven through the engine this reproduces the legacy
``build_spottune(...).run()`` RunResult exactly on the same seeds — the
seed-equivalence test in ``tests/test_tuner.py`` pins that.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.earlycurve import EarlyCurve
from repro.core.trial import TrialSpec
from repro.tuner.events import MetricReported
from repro.tuner.scheduler import CONTINUE, STOP, Decision, Scheduler

# last-big-delta index per curve prefix, shared process-wide: a trial's
# metric history (+ any preview extension) is always a prefix of its full
# deterministic curve — rollbacks truncate to a shorter prefix — so the
# plateau scan's ``last_big`` accumulator is a pure function of
# (trial curve, plateau_tol) and every replica of every sweep shares it.
_PLATEAU_CACHE: Dict[tuple, list] = {}
_PLATEAU_CACHE_MAX = 16384
# sorted global grid indices whose prefix passes converged(), per
# (trial key, tol, window) — derived from _PLATEAU_CACHE, same sharing
_OK_CACHE: Dict[tuple, list] = {}
_EMPTY_I64 = np.empty(0, np.int64)


def clear_plateau_caches() -> None:
    _PLATEAU_CACHE.clear()
    _OK_CACHE.clear()


def _last_big(key: tuple, hist, vals, n_total: int) -> np.ndarray:
    """Global ``last_big`` indices for the curve prefix of length n_total:
    entry j = the largest delta index i <= j with a relative step >= tol
    (-1 if none).  Extended incrementally as longer prefixes are seen."""
    ent = _PLATEAU_CACHE.get(key)
    if ent is None:
        if len(_PLATEAU_CACHE) >= _PLATEAU_CACHE_MAX:
            _PLATEAU_CACHE.clear()
        ent = _PLATEAU_CACHE[key] = [0, np.empty(0, np.int64)]
    have = ent[0]
    if n_total > have:
        tol = key[-1]
        n0 = len(hist)
        lo = max(have - 1, 0)          # previous tail value re-enters diff
        seq = np.empty(n_total - lo)
        if lo < n0:
            seq[:n0 - lo] = hist[lo:n_total] if n_total <= n0 else hist[lo:]
        if n_total > n0:
            seq[max(n0 - lo, 0):] = vals[max(lo - n0, 0):n_total - n0]
        # same float64 expression as EarlyCurve.converged, elementwise
        rel_big = (np.abs(np.diff(seq))
                   / np.maximum(np.abs(seq[:-1]), 1e-12)) >= tol
        idx = np.arange(lo, n_total - 1)
        prev = ent[1][have - 2] if have >= 2 else -1
        ext = np.maximum.accumulate(np.where(rel_big, idx, -1))
        ext = np.maximum(ext, prev)
        ent[1] = np.concatenate([ent[1][:max(have - 1, 0)], ext])
        ent[0] = n_total
    return ent[1]


class SpotTuneScheduler(Scheduler):
    # the preview answer is a pure function of the trial's combined
    # history+future metric sequence (plus its own stopped flag), so the
    # engine may memoize it within an allocation epoch
    preview_stable = True

    def __init__(self, theta: float = 0.7, mcnt: int = 3,
                 earlycurve: Optional[EarlyCurve] = None, seed: int = 0):
        self.theta = theta
        self.mcnt = mcnt
        self.ec = earlycurve or EarlyCurve()
        self.seed = seed
        self._stopped: set = set()
        self._preds: Optional[Dict[str, float]] = None
        self._phase = 1
        self._supplied: Optional[Dict[str, float]] = None
        self._fit_keys: List[str] = []

    # ------------------------------------------------------------- policy
    def on_trial_added(self, spec: TrialSpec) -> float:
        return math.floor(self.theta * spec.workload.max_trial_steps)

    def on_event(self, event, view) -> Decision:
        # convergence plateau (paper §III-C special case): metric histories
        # are updated before events fire, so this sees exactly the trajectory
        # the legacy loop checked once per advance
        if isinstance(event, MetricReported) and view.key not in self._stopped:
            if len(view.metrics_vals) >= self.ec.plateau_window \
                    and self.ec.converged(view.metrics_vals):
                self._stopped.add(view.key)
                return STOP
        return CONTINUE

    # ------------------------------------------- batched decision table
    # Only metric reports act; every other event class is inert by
    # construction of ``on_event`` above, which is the table contract.
    table_events = frozenset({MetricReported})

    def decision_table(self, entries) -> list:
        """θ plateau scan over a whole event batch: one ``_last_big`` lookup
        per trial instead of one ``converged()`` pass per metric point.

        Within one tick all of a trial's crossed points dispatch against the
        same post-advance history, so the scalar chain's per-point checks
        collapse to a single verdict on the full prefix — ``on_event``'s
        ``converged(metrics_vals)`` restated through the shared plateau
        accumulator (``lb[L-2] <= L-W-1`` == converged at length L)."""
        W = self.ec.plateau_window
        tol = self.ec.plateau_tol
        stopped = self._stopped
        out = []
        for kind, view, _payload in entries:
            if kind != "metric" or view.key in stopped:
                out.append(None)
                continue
            vals = view.metrics_vals
            L = len(vals)
            if L < W:
                out.append(None)
            elif W < 2:                # converged() degenerates to True
                stopped.add(view.key)
                out.append((True, False, None))
            else:
                lb = _last_big((view.key, tol), vals, (), L)
                if lb[L - 2] <= L - W - 1:
                    stopped.add(view.key)
                    out.append((True, False, None))
                else:
                    out.append(None)
        return out

    def preview_metrics(self, view, steps, vals, ticks) -> Optional[int]:
        """First upcoming metric point whose dispatch would STOP the trial.

        Vectorized mirror of the ``on_event`` plateau check: a point's
        handler sees the history through the *end of its tick* (same-tick
        points are appended before any of them dispatches), so convergence
        is evaluated on every tick-aligned prefix of history + preview."""
        if view.key in self._stopped:
            return None
        W = self.ec.plateau_window
        tol = self.ec.plateau_tol
        if W < 2:
            return 0        # converged() degenerates to True at any length
        hist = view.metrics_vals
        n0 = len(hist)
        m = len(vals)
        if n0 + m < W:
            return None
        # history + preview is always a prefix of the trial's deterministic
        # curve (rollbacks only truncate to shorter prefixes), so the plateau
        # accumulator is a pure function of (curve, tol) shared process-wide
        # across every replica — amortized O(new points) per call.  A delta
        # before the candidate window has index <= L-W-1 and never violates,
        # so the global last-big index decides exactly like the windowed scan.
        last_big = _last_big((view.key, tol), hist, vals, n0 + m)
        ticks = np.asarray(ticks)
        is_last = np.ones(m, bool)
        is_last[:-1] = ticks[1:] != ticks[:-1]
        ends = np.nonzero(is_last)[0]
        L = n0 + ends + 1                    # history length at each tick end
        ok = (L >= W) & (last_big[L - 2] <= L - W - 1)
        hits = np.nonzero(ok)[0]
        if not len(hits):
            return None
        e = int(ends[hits[0]])
        f = e
        while f > 0 and ticks[f - 1] == ticks[f]:
            f -= 1
        return f

    def preview_stop_grid(self, view, vals, lo: int, hi: int):
        """Sorted global grid indices g (covering at least through ``hi``)
        where a metric history of length g passes ``converged()``.  The
        engine combines this with its own point->tick map to find the first
        acting *tick end* without materializing the trajectory
        (``_preview_boundary`` fast path); grid index == prefix length
        because every grid point below ``lo`` is already in the history.
        None = nothing can fire.  Cached per curve: the index set is a pure
        function of (curve, tol, window) and only ever extends."""
        if view.key in self._stopped:
            return None
        W = self.ec.plateau_window
        if W < 2:
            # converged() is vacuously True from the first point
            return np.arange(lo, hi + 1, dtype=np.int64)
        if hi < W:
            return None
        tol = self.ec.plateau_tol
        lb = _last_big((view.key, tol), view.metrics_vals, vals, hi)
        ent = _OK_CACHE.get((view.key, tol, W))
        if ent is None:
            if len(_OK_CACHE) >= _PLATEAU_CACHE_MAX:
                _OK_CACHE.clear()
            ent = _OK_CACHE[(view.key, tol, W)] = [W - 1, _EMPTY_I64]
        if hi > ent[0]:
            g = np.arange(ent[0] + 1, hi + 1)
            g = g[lb[g - 2] <= g - W - 1]
            if len(g):
                ent[1] = np.concatenate([ent[1], g])
            ent[0] = hi
        return ent[1]

    def _predict_all(self, views: Sequence) -> Dict[str, float]:
        preds: Dict[str, float] = {}
        supplied = self._supplied
        self._supplied = None
        jobs, job_keys = [], []
        for v in views:
            if self.theta >= 1.0 or v.key in self._stopped:
                preds[v.key] = v.metrics_vals[-1] if v.metrics_vals else 1e9
            elif supplied is not None and v.key in supplied:
                preds[v.key] = supplied[v.key]   # pre-batched by the sweep
            else:
                jobs.append((v.metrics_steps, v.metrics_vals,
                             v.spec.workload.max_trial_steps))
                job_keys.append(v.key)
        if jobs:
            for key, p in zip(job_keys, self.run_idle_fits(jobs)):
                preds[key] = p
        return preds

    # --------------------------------------------- sweep batching protocol
    def idle_fit_jobs(self, views: Sequence) -> Optional[list]:
        if self._phase != 1 or self.theta >= 1.0:
            return None
        jobs, keys = [], []
        for v in views:
            if v.key not in self._stopped:
                jobs.append((v.metrics_steps, v.metrics_vals,
                             v.spec.workload.max_trial_steps))
                keys.append(v.key)
        if not jobs:
            return None
        self._fit_keys = keys
        return jobs

    def run_idle_fits(self, jobs: list) -> list:
        batch = getattr(self.ec, "predict_final_batch", None)
        if batch is not None:        # one dispatch per stage-length bucket
            return batch(jobs, seed=self.seed)
        return [self.ec.predict_final(steps, vals, tgt, seed=self.seed)
                for steps, vals, tgt in jobs]

    def set_idle_fits(self, preds: list) -> None:
        self._supplied = dict(zip(self._fit_keys, preds))

    def on_idle(self, views: Sequence) -> Dict[str, float]:
        if self._phase == 1:
            self._phase = 2
            # phase 2 (Algorithm 1 l.48-53): predict finals, continue top-mcnt
            self._preds = self._predict_all(views)
            if self.theta >= 1.0:
                return {}
            order = sorted(views, key=lambda v: self._preds[v.key])
            promotions: Dict[str, float] = {}
            for v in order[: self.mcnt]:
                max_steps = v.spec.workload.max_trial_steps
                if v.key not in self._stopped and v.steps < max_steps:
                    promotions[v.key] = max_steps
            return promotions
        return {}

    # ------------------------------------------------------------- results
    def predictions(self, views: Sequence) -> Dict[str, float]:
        if self._preds is None:  # run never reached idle (out-of-engine use)
            self._preds = self._predict_all(views)
        return dict(self._preds)


class AdaptiveSpotTuneScheduler(SpotTuneScheduler):
    """SpotTune's θ-budget policy over an *adaptive* searcher.

    Phase 1 becomes a sequential-batch search: at every engine idle the
    scheduler asks the Tuner for ``suggest_batch`` fresh suggestions — the
    searcher (``TrimTunerSearcher`` cost-aware BO by default,
    ``AdaptiveGridSearcher`` Hamming-halving as the legacy option) narrows
    its proposals around the results reported so far — until the searcher
    dries up.  Suggestions may be *sub-sampled* (``TrialSpec.budget_frac``
    < 1, TrimTuner's cheap bootstrap wave): their budget is ``theta *
    budget_frac`` of the full run.  Once the search is dry, a fidelity-gap
    round (``_fidelity_promotions``) verifies every under-sampled trial
    whose declared LR schedule decays beyond the steps it ran at the
    standard θ budget, so the final selection never extrapolates across
    curve stages a cheap run couldn't see; then the normal SpotTune
    phase 2 promotes the top-``mcnt`` to the full budget.  Requires a
    Tuner constructed with ``initial_trials`` (so the searcher is not
    drained up front)."""

    # the TrimTuner feedback loop (adaptive suggestion waves keyed off
    # results as they land) stays on the verbatim scalar chain: correctness
    # does not depend on it, but keeping one production policy on the
    # scalar path pins that path's equivalence coverage in the sweep cube
    decision_table = None
    table_events = frozenset()

    def __init__(self, theta: float = 0.7, mcnt: int = 3,
                 earlycurve: Optional[EarlyCurve] = None, seed: int = 0,
                 suggest_batch: int = 4):
        super().__init__(theta=theta, mcnt=mcnt, earlycurve=earlycurve,
                         seed=seed)
        self.suggest_batch = suggest_batch
        self._search_done = False
        self._fidelity_done = False

    def on_trial_added(self, spec: TrialSpec) -> float:
        # honor sub-sampled suggestions (TrimTuner's cheap bootstrap wave):
        # the budget is theta * budget_frac of the full run
        return math.floor(
            self.theta * spec.budget_frac * spec.workload.max_trial_steps)

    def request_suggestions(self, views: Sequence) -> int:
        if self._phase != 1 or self._search_done:
            return 0
        return self.suggest_batch

    def suggestions_added(self, n: int) -> None:
        if n == 0:
            self._search_done = True

    def _fidelity_promotions(self, views: Sequence) -> Dict[str, float]:
        """Fidelity-gap scan: a sub-sampled trial whose declared LR schedule
        (``TrialSpec.decay_steps`` — known a priori, not ground truth)
        drops again between its observed steps and the standard θ budget
        cannot be extrapolated — EarlyCurve has not seen the post-drop
        stage, and the misprediction would evict the trial from the
        shortlist before phase 2 ever ranks it.  Exactly those trials are
        verified at the θ budget (resuming from their checkpoints, paying
        only the delta steps); smooth single-stage curves extrapolate fine
        and stay cheap."""
        promotions: Dict[str, float] = {}
        for v in views:
            std = math.floor(self.theta * v.spec.workload.max_trial_steps)
            if v.key in self._stopped or v.steps >= std:
                continue
            ds = v.spec.decay_steps()
            if ds is not None and math.floor(v.steps / ds) < math.floor(std / ds):
                promotions[v.key] = std
        return promotions

    def idle_fit_jobs(self, views: Sequence) -> Optional[list]:
        if self._phase == 1 and not self._fidelity_done \
                and self._fidelity_promotions(views):
            # this idle resumes under-sampled trials instead of ranking —
            # batched curve fits would be computed only to be thrown away
            return None
        return super().idle_fit_jobs(views)

    def on_idle(self, views: Sequence) -> Dict[str, float]:
        if self._phase == 1 and not self._fidelity_done:
            promotions = self._fidelity_promotions(views)
            self._fidelity_done = True
            if promotions:
                return promotions
        return super().on_idle(views)
