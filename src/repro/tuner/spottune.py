"""The paper's search policy, re-expressed as a pluggable Scheduler.

SpotTune's Algorithm 1 policy, extracted from the old monolithic orchestrator
loop and restated against the Scheduler protocol:

  * every trial's initial budget is ``floor(theta * max_trial_steps)``;
  * a trial whose metric plateaus (EarlyCurve's §III-C special case) is
    STOPped early;
  * when the engine drains (phase-1 idle), EarlyCurve extrapolates every
    trial's final metric from its partial trajectory (seeded, so ranking is
    reproducible), and the top-``mcnt`` predicted trials are promoted to the
    full ``max_trial_steps`` budget — in predicted-rank order, which is also
    the redeployment order (this preserves the legacy RNG-draw sequence);
  * the second idle ends the run; the final ranking keeps the *phase-1*
    predictions (the paper reports selection accuracy of the early
    extrapolation, not of the finished winners).

Driven through the engine this reproduces the legacy
``build_spottune(...).run()`` RunResult exactly on the same seeds — the
seed-equivalence test in ``tests/test_tuner.py`` pins that.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

from repro.core.earlycurve import EarlyCurve
from repro.core.trial import TrialSpec
from repro.tuner.events import MetricReported
from repro.tuner.scheduler import CONTINUE, STOP, Decision, Scheduler


class SpotTuneScheduler(Scheduler):
    def __init__(self, theta: float = 0.7, mcnt: int = 3,
                 earlycurve: Optional[EarlyCurve] = None, seed: int = 0):
        self.theta = theta
        self.mcnt = mcnt
        self.ec = earlycurve or EarlyCurve()
        self.seed = seed
        self._stopped: set = set()
        self._preds: Optional[Dict[str, float]] = None
        self._phase = 1

    # ------------------------------------------------------------- policy
    def on_trial_added(self, spec: TrialSpec) -> float:
        return math.floor(self.theta * spec.workload.max_trial_steps)

    def on_event(self, event, view) -> Decision:
        # convergence plateau (paper §III-C special case): metric histories
        # are updated before events fire, so this sees exactly the trajectory
        # the legacy loop checked once per advance
        if isinstance(event, MetricReported) and view.key not in self._stopped:
            if len(view.metrics_vals) >= self.ec.plateau_window \
                    and self.ec.converged(view.metrics_vals):
                self._stopped.add(view.key)
                return STOP
        return CONTINUE

    def _predict_all(self, views: Sequence) -> Dict[str, float]:
        preds: Dict[str, float] = {}
        jobs, job_keys = [], []
        for v in views:
            if self.theta >= 1.0 or v.key in self._stopped:
                preds[v.key] = v.metrics_vals[-1] if v.metrics_vals else 1e9
            else:
                jobs.append((v.metrics_steps, v.metrics_vals,
                             v.spec.workload.max_trial_steps))
                job_keys.append(v.key)
        if jobs:
            batch = getattr(self.ec, "predict_final_batch", None)
            if batch is not None:    # one dispatch per stage-length bucket
                for key, p in zip(job_keys, batch(jobs, seed=self.seed)):
                    preds[key] = p
            else:                    # custom predictor without a batch path
                for key, (steps, vals, tgt) in zip(job_keys, jobs):
                    preds[key] = self.ec.predict_final(steps, vals, tgt,
                                                       seed=self.seed)
        return preds

    def on_idle(self, views: Sequence) -> Dict[str, float]:
        if self._phase == 1:
            self._phase = 2
            # phase 2 (Algorithm 1 l.48-53): predict finals, continue top-mcnt
            self._preds = self._predict_all(views)
            if self.theta >= 1.0:
                return {}
            order = sorted(views, key=lambda v: self._preds[v.key])
            promotions: Dict[str, float] = {}
            for v in order[: self.mcnt]:
                max_steps = v.spec.workload.max_trial_steps
                if v.key not in self._stopped and v.steps < max_steps:
                    promotions[v.key] = max_steps
            return promotions
        return {}

    # ------------------------------------------------------------- results
    def predictions(self, views: Sequence) -> Dict[str, float]:
        if self._preds is None:  # run never reached idle (out-of-engine use)
            self._preds = self._predict_all(views)
        return dict(self._preds)
