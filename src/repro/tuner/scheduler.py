"""Scheduler/Searcher protocols: the policy half of the tuner split.

SpotTune's engine (market + provisioning + checkpoint/restore + refund
accounting) is policy-free; *what to run and when to stop it* is delegated to
two pluggable pieces, syne-tune style:

  Searcher   suggests trial configurations (``TrialSpec``s) — grid, random,
             model-based, ... (``repro.tuner.searchers``)
  Scheduler  consumes the engine's event stream (``repro.tuner.events``) and
             returns ``Decision``s — continue, pause at a checkpoint, stop for
             good, or promote to a larger step budget.  The paper's θ +
             EarlyCurve policy is one such scheduler
             (``repro.tuner.spottune.SpotTuneScheduler``); ASHA is another
             (``repro.tuner.searchers.ASHAScheduler``).

Schedulers observe trials through *views*: any object with the attributes
``spec``, ``key``, ``steps``, ``target_steps``, ``metrics_steps``,
``metrics_vals`` and ``stopped``.  The engine passes its own ``TrialState``;
out-of-engine drivers (e.g. ``examples/e2e_hpt_train.py``, which runs real JAX
training) pass the lightweight ``TrialView`` below.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence

from repro.core.trial import TrialSpec


# ---------------------------------------------------------------------------
# decisions
# ---------------------------------------------------------------------------


class DecisionKind(enum.Enum):
    CONTINUE = "continue"   # keep running
    PAUSE = "pause"         # checkpoint + release; park until promoted
    STOP = "stop"           # trial is done (early): checkpoint + finish
    PROMOTE = "promote"     # raise the trial's step budget (resumes if parked)


@dataclasses.dataclass(frozen=True)
class Decision:
    kind: DecisionKind
    target_steps: Optional[float] = None  # only for PROMOTE


CONTINUE = Decision(DecisionKind.CONTINUE)
PAUSE = Decision(DecisionKind.PAUSE)
STOP = Decision(DecisionKind.STOP)


def PROMOTE(target_steps: float) -> Decision:
    return Decision(DecisionKind.PROMOTE, target_steps=target_steps)


# ---------------------------------------------------------------------------
# trial view
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrialView:
    """Minimal duck-type of the engine's TrialState, for drivers that run
    trials themselves (real training loops) but want engine-free policy."""

    spec: TrialSpec
    steps: float = 0.0
    target_steps: float = 0.0
    metrics_steps: List[int] = dataclasses.field(default_factory=list)
    metrics_vals: List[float] = dataclasses.field(default_factory=list)
    stopped: bool = False

    @property
    def key(self) -> str:
        return self.spec.key


# ---------------------------------------------------------------------------
# protocols (as inheritable no-op base classes)
# ---------------------------------------------------------------------------


class Scheduler:
    """Base scheduler: runs every trial to its workload's full budget.

    Subclass hooks:

      on_trial_added(spec) -> target_steps | None
          Called once per suggested trial, before the run.  Return the initial
          step budget (None = the workload's ``max_trial_steps``).
      on_event(event, view) -> Decision | None
          Called for every engine event; None is treated as CONTINUE.
      take_promotions() -> {key: target_steps}
          Drained by the engine after every event: asynchronous promotions of
          *other* trials (e.g. ASHA un-pausing a rung survivor).  Order is the
          resume order.
      on_idle(views) -> {key: target_steps}
          Called when no trial is running or waiting.  Return promotions to
          resume paused/finished trials with a new budget; an empty dict ends
          the tuning run.  Order is the (re)deployment order — it matters for
          reproducibility because provisioning consumes seeded RNG draws.
      preview_metrics(view, steps, vals, ticks) -> index | None
          Optional fast-path contract: given the metric points a running
          trial will cross before its next lifecycle boundary (arrays of
          step, value, and the tick each would be observed at), return the
          index of the first point whose ``on_event`` would do anything
          other than a side-effect-free CONTINUE — or None if every point
          is inert.  A scheduler that implements this promises the engine
          may *silently* append the inert points to the trial's history
          without dispatching ``MetricReported`` for them; the flagged
          point (and its same-tick companions) still dispatches normally.
          Must be pure: the engine may re-preview overlapping windows.
      request_suggestions(views) -> int
          Consulted at every engine idle, before promotions: how many fresh
          searcher suggestions to admit (0 = none).  Enables unbounded /
          adaptive search without draining the searcher up front.
      suggestions_added(n)
          Follow-up to a non-zero request: how many trials the searcher
          actually produced (0 = it is exhausted).
      idle_fit_jobs(views) -> [(steps, vals, target_step), ...] | None
          Optional sweep batching hook: the curve-fit workload the next
          ``on_idle`` needs, exposed so a sweep runner can stack the fits of
          many replicas into one dispatch.  ``run_idle_fits(jobs)`` must
          compute them locally; ``set_idle_fits(preds)`` hands results back
          (in job order) before ``on_idle`` is called.
      predictions(views) -> {key: predicted_final_metric}
      rank(views) -> [key, ...]   best first (lower metric = better)

    Batched decision tables (SoA fast path).  A scheduler may opt into
    answering a whole event batch at once by setting ``table_events`` and
    overriding ``decision_table``; see the attribute docs below.  The SoA
    sweep stepper (``repro.sweep.soa``) then replaces its per-row scalar
    dispatch chain with one table call per replica per round; policies
    without the capability keep the verbatim per-event chain.
    """

    #: Decision-table capability.  ``None`` (the base) = scalar chain only.
    #: An opted-in scheduler overrides this with a method
    #: ``decision_table(entries) -> [answer, ...]`` where ``entries`` is a
    #: list of ``("metric", view, [(step, value), ...])`` and
    #: ``("revoked", view, (lost_steps, ckpt_steps))`` tuples in engine
    #: chain order (per trial: its metric batch strictly before its
    #: revocation), and each answer is ``None`` (every dispatch would be a
    #: side-effect-free CONTINUE) or ``(stop, pause, target)`` — the
    #: cumulative flag effect the per-event ``Decision``s would have had
    #: (``stop``/``pause`` booleans, ``target`` a new step budget or None).
    #: The contract mirrors the scalar chain exactly:
    #:   * processing entry i must leave the scheduler in the same state as
    #:     dispatching entry i's events through ``on_event`` in order;
    #:   * events whose class is NOT in ``table_events`` are promised inert
    #:     (CONTINUE, no observable state change), so the engine may skip
    #:     dispatching them entirely — including ``TrialStarted`` at deploy
    #:     time and the lifecycle narration events;
    #:   * the table must not read view attributes the engine mutates while
    #:     applying answers (``stopped``/``pause_requested``/
    #:     ``target_steps``/``status``) — it maintains its own state;
    #:   * asynchronous promotions are staged as usual and drained once via
    #:     ``take_promotions`` after the whole batch, which must be
    #:     equivalent to the scalar path's per-event drain (promotions only
    #:     ever touch parked — non-running — trials), with the *chronological*
    #:     staging order preserved.
    decision_table = None

    #: Event classes the decision table acts on.  Everything else is
    #: declared inert per the contract above.  Only ``MetricReported`` and
    #: ``TrialRevoked`` are batchable; a table declaring any other class
    #: falls back to the scalar chain in the stepper.
    table_events: frozenset = frozenset()

    def on_trial_added(self, spec: TrialSpec) -> Optional[float]:
        return None

    def on_event(self, event, view) -> Optional[Decision]:
        return CONTINUE

    def take_promotions(self) -> Dict[str, float]:
        return {}

    def on_idle(self, views: Sequence) -> Dict[str, float]:
        return {}

    def preview_metrics(self, view, steps, vals, ticks) -> Optional[int]:
        return None          # base = no preview capability (conservative)

    def request_suggestions(self, views: Sequence) -> int:
        return 0

    def suggestions_added(self, n: int) -> None:
        pass

    def idle_fit_jobs(self, views: Sequence) -> Optional[list]:
        return None

    def run_idle_fits(self, jobs: list) -> list:
        raise NotImplementedError

    def set_idle_fits(self, preds: list) -> None:
        pass

    def predictions(self, views: Sequence) -> Dict[str, float]:
        return {v.key: (v.metrics_vals[-1] if v.metrics_vals else 1e9)
                for v in views}

    def rank(self, views: Sequence) -> List[str]:
        preds = self.predictions(views)
        return [v.key for v in sorted(views, key=lambda v: preds[v.key])]


class Searcher:
    """Base searcher: suggests nothing.  Subclasses yield TrialSpecs.

    ``supports_continuous`` declares whether the searcher can operate on a
    ``SearchSpace`` with continuous domains (``Uniform``/``LogUniform``/
    ``IntUniform``) or requires a finite, enumerable grid.  The registry
    (``repro.tuner.registry.make_searcher``) enforces the pairing: asking a
    grid-only searcher to search a continuous space is a ValueError, not a
    silent truncation."""

    #: can this searcher propose configs off a finite grid?
    supports_continuous = False

    def suggest(self) -> Optional[TrialSpec]:
        return None

    def on_result(self, key: str, metric: Optional[float]) -> None:
        """Feedback hook for adaptive searchers; default ignores it."""
