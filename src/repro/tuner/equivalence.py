"""Fast/exact equivalence harness for the execution engine.

The event-driven fast path (``EngineConfig(exact_ticks=False)``, the default)
claims to be *equivalent* to the legacy tick-for-tick loop: every externally
observable outcome — dollars billed and refunded, per-allocation billing
records, trial finish times, full per-trial metric histories, the event log —
must match.  Step counters (``steps``, ``lost_steps``, ``free_steps``) are
accumulated tick-by-tick on the exact path but as one fused sum per window on
the fast path, so they may differ by float-rounding dust; they are compared
to a tight relative tolerance instead of bit-for-bit.

``compare_runs`` runs the same tuning problem through both paths on fresh
market replicas and returns a report of any differences (empty == equivalent).
``tests/test_simcore_equiv.py`` pins this across seeds; ``benchmarks/run.py
--json`` re-checks it while measuring the speedup.
"""

from __future__ import annotations

import math
from typing import Callable, List, Optional

from repro.core.market import SpotMarket
from repro.core.provisioner import ZeroRevPred
from repro.core.trial import SimTrialBackend, Workload, make_trials
from repro.tuner.engine import EngineConfig, ExecutionEngine, build_engine
from repro.tuner.searchers import ListSearcher
from repro.tuner.spottune import SpotTuneScheduler
from repro.tuner.tuner import RunResult, Tuner

STEP_RTOL = 1e-9


def _close(a: float, b: float, rtol: float = STEP_RTOL) -> bool:
    return math.isclose(a, b, rel_tol=rtol, abs_tol=1e-9)


def _diff_events(fast: List[tuple], exact: List[tuple], out: List[str]) -> None:
    if len(fast) != len(exact):
        out.append(f"event count: fast={len(fast)} exact={len(exact)}")
        return
    for i, (ef, ee) in enumerate(zip(fast, exact)):
        if len(ef) != len(ee) or ef[:3] != ee[:3]:
            out.append(f"event[{i}]: fast={ef} exact={ee}")
            continue
        for f, e in zip(ef[3:], ee[3:]):
            if isinstance(f, dict):           # release billing record
                for key in ("inst", "held_s", "revoked", "cost", "refund"):
                    if f[key] != e[key]:
                        out.append(f"event[{i}] release {key}: "
                                   f"fast={f[key]} exact={e[key]}")
            elif isinstance(f, float):
                if not _close(f, e):
                    out.append(f"event[{i}] payload: fast={ef} exact={ee}")
            elif f != e:
                out.append(f"event[{i}] payload: fast={ef} exact={ee}")


def compare_engines(fast: ExecutionEngine, exact: ExecutionEngine,
                    fast_res: RunResult, exact_res: RunResult) -> List[str]:
    """Diff two finished runs; returns human-readable mismatch lines."""
    out: List[str] = []
    if fast.market.billed != exact.market.billed:
        out.append(f"billed: fast={fast.market.billed!r} "
                   f"exact={exact.market.billed!r}")
    if fast.market.refunded != exact.market.refunded:
        out.append(f"refunded: fast={fast.market.refunded!r} "
                   f"exact={exact.market.refunded!r}")
    if fast.t != exact.t:
        out.append(f"engine.t: fast={fast.t} exact={exact.t}")
    fs = {s.key: s for s in fast.states}
    es = {s.key: s for s in exact.states}
    if set(fs) != set(es):
        out.append(f"trial keys differ: {set(fs) ^ set(es)}")
        return out
    for key, f in fs.items():
        e = es[key]
        if f.status != e.status:
            out.append(f"{key} status: fast={f.status} exact={e.status}")
        if f.finish_time != e.finish_time:
            out.append(f"{key} finish_time: fast={f.finish_time} "
                       f"exact={e.finish_time}")
        if f.metrics_steps != e.metrics_steps:
            out.append(f"{key} metrics_steps differ")
        if f.metrics_vals != e.metrics_vals:
            out.append(f"{key} metrics_vals differ")
        if f.redeployments != e.redeployments:
            out.append(f"{key} redeployments: fast={f.redeployments} "
                       f"exact={e.redeployments}")
        for attr in ("steps", "free_steps", "lost_steps", "ckpt_seconds",
                     "restore_seconds"):
            if not _close(getattr(f, attr), getattr(e, attr)):
                out.append(f"{key} {attr}: fast={getattr(f, attr)!r} "
                           f"exact={getattr(e, attr)!r}")
    _diff_events(fast.events, exact.events, out)
    if fast_res.predicted_rank != exact_res.predicted_rank:
        out.append("predicted_rank differs")
    if fast_res.jct != exact_res.jct:
        out.append(f"jct: fast={fast_res.jct} exact={exact_res.jct}")
    return out


def run_one(workload: Workload, exact_ticks: bool, market_seed: int = 3,
            seed: int = 0, theta: float = 0.7, mcnt: int = 3,
            days: float = 12.0, revpred_factory: Optional[Callable] = None,
            scheduler_factory: Optional[Callable] = None,
            searcher_factory: Optional[Callable] = None,
            initial_trials: Optional[int] = None,
            n_trials: Optional[int] = None,
            ledger: Optional[str] = None, **engine_kw):
    """One tuning run on a fresh market replica -> (engine, RunResult).

    ``searcher_factory(workload)`` swaps the default ListSearcher prefix
    (paired policies like PBT bring their own explore searcher);
    ``initial_trials`` passes through to the Tuner for incremental
    suggestion; ``ledger`` forces the market's allocation-ledger layout
    ("scalar" | "columnar", None = default)."""
    market = SpotMarket(days=days, seed=market_seed, ledger=ledger)
    backend = SimTrialBackend(market.pool)
    revpred = (revpred_factory or (lambda m: ZeroRevPred()))(market)
    engine = build_engine(market, backend, revpred, seed=seed,
                          exact_ticks=exact_ticks, **engine_kw)
    scheduler = (scheduler_factory or
                 (lambda: SpotTuneScheduler(theta=theta, mcnt=mcnt,
                                            seed=seed)))()
    if searcher_factory is not None:
        assert n_trials is None, \
            "n_trials only trims the default ListSearcher; cap the " \
            "searcher_factory's own suggestion budget instead"
        searcher = searcher_factory(workload)
    else:
        trials = make_trials(workload)
        if n_trials is not None:
            trials = trials[:n_trials]
        searcher = ListSearcher(trials)
    res = Tuner(engine, scheduler, searcher,
                initial_trials=initial_trials).run()
    return engine, res


def compare_runs(workload: Workload, **kw) -> List[str]:
    """Run fast and exact on fresh market replicas and diff them."""
    fast_eng, fast_res = run_one(workload, exact_ticks=False, **kw)
    exact_eng, exact_res = run_one(workload, exact_ticks=True, **kw)
    return compare_engines(fast_eng, exact_eng, fast_res, exact_res)


def compare_sweep_modes(specs, use_tables: bool = True) -> List[str]:
    """Run one ScenarioSpec grid through the SoA stepper and through the
    generator round-robin path on independently built replica sets (shared
    caches dropped before each, so neither warms the other) and diff every
    replica's engine pairwise with ``compare_engines``.  Empty == the SoA
    fast path is bit-exact.  ``use_tables=False`` pins the stepper to the
    scalar lifecycle chain (no batched decision tables)."""
    from repro.sweep import runner as runner_mod
    from repro.sweep.soa import SoaSweep, soa_supported

    runner = runner_mod.SweepRunner()
    runner_mod.clear_shared_caches()
    soa_tuners = runner.prepare(specs)
    if not soa_supported(soa_tuners):
        return ["grid not soa_supported — nothing to compare"]
    SoaSweep(soa_tuners, use_tables=use_tables).run()

    runner_mod.clear_shared_caches()
    gen_res = runner.run(specs, mode="batched")

    out: List[str] = []
    for spec, ts, rr in zip(specs, soa_tuners, gen_res.replicas):
        label = (f"{spec.workload}/{spec.scheduler}"
                 f"/m{spec.market_seed}/e{spec.engine_seed}")
        if ts.result is None:
            out.append(f"[{label}] soa replica never finished")
            continue
        hist = {s.key: (list(s.metrics_steps), list(s.metrics_vals))
                for s in ts.engine.views()}
        if hist != rr.metrics:
            out.append(f"[{label}] metric histories differ")
        for field in ("cost", "refunded", "jct", "predicted_rank",
                      "redeployments", "events"):
            a, b = getattr(ts.result, field), getattr(rr.result, field)
            if a != b:
                out.append(f"[{label}] result.{field}: soa={a!r} gen={b!r}")
        for field in ("steps_total", "free_steps", "lost_steps",
                      "ckpt_seconds", "restore_seconds"):
            if not _close(getattr(ts.result, field), getattr(rr.result, field)):
                out.append(f"[{label}] result.{field}: "
                           f"soa={getattr(ts.result, field)!r} "
                           f"gen={getattr(rr.result, field)!r}")
    return out


def compare_service_modes(specs, policy: str = "fifo",
                          policy_params: Optional[dict] = None) -> List[str]:
    """Pin the tuning service's degenerate case: one tenant, contention
    disabled.  The same ScenarioSpec grid runs once as a single submitted
    ``StudySpec`` through ``TuningService`` (under any fairness policy —
    with one study, admission must be inert) and once through the plain
    ``SweepRunner`` SoA path, on independently built replica sets (shared
    caches dropped before each).  Billing records, event logs, metric
    histories, and results must match bit-exact; empty == equivalent."""
    from repro.service import StudySpec, StudyStatus, TuningService
    from repro.sweep import runner as runner_mod
    from repro.sweep.soa import SoaSweep, soa_supported

    runner_mod.clear_shared_caches()
    svc = TuningService(policy=policy, policy_params=policy_params,
                        contention=False)
    sid = svc.submit(StudySpec(tenant="t0", specs=tuple(specs)))
    svc.run_until_complete()
    svc_rec = svc.registry.get(sid)

    runner = runner_mod.SweepRunner()
    runner_mod.clear_shared_caches()
    ref = runner.prepare(specs)
    if not soa_supported(ref):
        return ["grid not soa_supported — nothing to compare"]
    SoaSweep(ref).run()

    out: List[str] = []
    if svc_rec.status is not StudyStatus.DONE:
        out.append(f"service study status: {svc_rec.status}")
    if len(svc_rec.records) != len(specs):
        out.append(f"streamed records: service={len(svc_rec.records)} "
                   f"expected={len(specs)}")
    for spec, tv, tr in zip(specs, svc_rec.tuners, ref):
        label = (f"{spec.workload}/{spec.scheduler}"
                 f"/m{spec.market_seed}/e{spec.engine_seed}")
        if tv.result is None or tr.result is None:
            out.append(f"[{label}] replica never finished")
            continue
        sub = compare_engines(tv.engine, tr.engine, tv.result, tr.result)
        out.extend(f"[{label}] {line}" for line in sub)
        for field in ("cost", "refunded", "jct", "predicted_rank",
                      "redeployments", "events"):
            a, b = getattr(tv.result, field), getattr(tr.result, field)
            if a != b:
                out.append(f"[{label}] result.{field}: "
                           f"service={a!r} runner={b!r}")
        for field in ("steps_total", "free_steps", "lost_steps",
                      "ckpt_seconds", "restore_seconds"):
            if not _close(getattr(tv.result, field),
                          getattr(tr.result, field)):
                out.append(f"[{label}] result.{field}: "
                           f"service={getattr(tv.result, field)!r} "
                           f"runner={getattr(tr.result, field)!r}")
    return out


def compare_ledger_modes(specs) -> List[str]:
    """Run one ScenarioSpec grid through the SoA stepper twice — once under
    the scalar allocation ledger (the reference implementation) and once
    under the columnar one — on independently built replica sets (shared
    caches dropped before each) and diff every observable outcome strictly.
    Empty == the columnar ledger's batched crossing search and prefix-sum
    billing are bit-exact against the scalar acquire/release loop."""
    import dataclasses

    from repro.sweep import runner as runner_mod
    from repro.sweep.soa import SoaSweep, soa_supported

    runner = runner_mod.SweepRunner()
    by_kind = {}
    for kind in ("scalar", "columnar"):
        runner_mod.clear_shared_caches()
        tuners = runner.prepare([dataclasses.replace(s, ledger=kind)
                                 for s in specs])
        if not soa_supported(tuners):
            return ["grid not soa_supported — nothing to compare"]
        SoaSweep(tuners).run()
        by_kind[kind] = tuners

    out: List[str] = []
    for spec, ts, tc in zip(specs, by_kind["scalar"], by_kind["columnar"]):
        label = (f"{spec.workload}/{spec.scheduler}"
                 f"/m{spec.market_seed}/e{spec.engine_seed}")
        if ts.result is None or tc.result is None:
            out.append(f"[{label}] replica never finished")
            continue
        assert ts.engine.market.ledger.kind == "scalar"
        assert tc.engine.market.ledger.kind == "columnar"
        for field in ("cost", "refunded", "jct", "predicted_rank",
                      "redeployments", "events"):
            a, b = getattr(ts.result, field), getattr(tc.result, field)
            if a != b:
                out.append(f"[{label}] result.{field}: "
                           f"scalar={a!r} columnar={b!r}")
        if (ts.engine.market.billed != tc.engine.market.billed
                or ts.engine.market.refunded != tc.engine.market.refunded):
            out.append(f"[{label}] market totals: "
                       f"scalar=({ts.engine.market.billed!r}, "
                       f"{ts.engine.market.refunded!r}) "
                       f"columnar=({tc.engine.market.billed!r}, "
                       f"{tc.engine.market.refunded!r})")
    return out
