"""Typed HP domains and the SearchSpace they compose into.

The paper's tuner assumed one shape of search space everywhere: a product of
2-value dims (`Workload.hp_space` tuples), enumerated once into a 16-point
grid whose positional index doubled as trial identity.  This module makes
the space a first-class value so the same engine/policy stack covers
continuous relaxations (TrimTuner, Scavenger-style config x HP products)
with the grid as the degenerate all-finite case:

  Choice      unordered finite set (categorical) — neighbor = any other value
  Ordinal     ordered finite set — neighbor = adjacent value (the legacy
              2-value grid dims; ``SearchSpace.from_legacy`` maps them here)
  Uniform     continuous interval, linear scale
  LogUniform  continuous interval, log scale (learning rates)
  IntUniform  integer interval (decay steps, tree counts)

A ``SearchSpace`` is an ordered tuple of named domains with

  * seeded sampling (``sample``) and single-dim perturbation (``neighbor``),
  * vectorized encode/decode to a normalized ``[0, 1]^d`` feature matrix —
    the representation every numpy/jax hot path (BO posteriors, GP kernels)
    consumes,
  * process-independent config hashing (``config_hash`` / ``config_key``)
    for duplicate detection and trial identity off the grid,
  * grid enumeration (``grid``) when every domain is finite — bit-compatible
    with the legacy ``Workload.hp_grid()`` product order,
  * per-dim *anchor* values (``anchor_values``): the lattice the simulation
    backend interpolates its ground-truth curves between (finite domains
    anchor on their own values; continuous domains on their bounds).

Everything is a frozen dataclass: spaces ride inside ``Workload`` (itself
frozen/hashable) and key process-wide memo caches.  This module deliberately
imports nothing from the rest of the tuner so ``repro.core.trial`` can use
it without an import cycle.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np


# ---------------------------------------------------------------------------
# domains
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Domain:
    """One hyper-parameter dimension.  Subclasses define the value set."""

    #: continuous domains admit values outside any finite lattice
    is_continuous = False

    # -- value set ---------------------------------------------------------
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError

    def contains(self, value) -> bool:
        raise NotImplementedError

    # -- normalized feature space -----------------------------------------
    def encode(self, value) -> float:
        """Map a value into [0, 1] (the model-facing representation)."""
        raise NotImplementedError

    def decode(self, u: float):
        """Inverse of ``encode`` (up to rounding for discrete domains)."""
        raise NotImplementedError

    # -- structure ---------------------------------------------------------
    def anchor_values(self) -> tuple:
        """The lattice points ground-truth interpolation anchors on."""
        raise NotImplementedError

    def neighbor_values(self, value) -> list:
        """Finite domains: adjacent-move candidates, preferred first.
        Continuous domains return [] (use ``neighbor``)."""
        return []

    def neighbor(self, value, rng: np.random.Generator):
        """A perturbed value near ``value`` (PBT explore's one-dim move)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Choice(Domain):
    """Unordered finite set.  ``encode`` uses the declared position (the
    model sees *some* embedding; for true categoricals with >2 values a
    one-hot would be better, but every paper workload is binary)."""

    values: tuple

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        assert len(self.values) >= 1

    def sample(self, rng):
        return self.values[int(rng.integers(len(self.values)))]

    def contains(self, value):
        return value in self.values

    def encode(self, value):
        return self.values.index(value) / max(len(self.values) - 1, 1)

    def decode(self, u):
        i = int(round(float(u) * max(len(self.values) - 1, 1)))
        return self.values[min(max(i, 0), len(self.values) - 1)]

    def anchor_values(self):
        return self.values

    def neighbor_values(self, value):
        return [v for v in self.values if v != value]

    def neighbor(self, value, rng):
        others = [v for v in self.values if v != value]
        if not others:
            return value
        return others[int(rng.integers(len(others)))]


@dataclasses.dataclass(frozen=True)
class Ordinal(Choice):
    """Ordered finite set: neighbors are adjacent values.  The legacy grid
    dims map here, so PBT's perturb-to-adjacent-grid-value is literally
    ``Ordinal.neighbor``."""

    def neighbor_values(self, value):
        j = self.values.index(value)
        return [self.values[nj] for nj in (j + 1, j - 1)
                if 0 <= nj < len(self.values)]

    def neighbor(self, value, rng):
        cands = self.neighbor_values(value)
        if not cands:
            return value
        return cands[int(rng.integers(len(cands)))]


@dataclasses.dataclass(frozen=True)
class Uniform(Domain):
    """Continuous interval on a linear scale.

    ``anchors`` optionally overrides the ground-truth anchor lattice (and
    its order): ``continuous_variant`` relaxes a legacy 2-value dim into
    ``Uniform(min, max, anchors=<values in declared order>)`` so the
    anchor product indices — and with them the simulated anchor curves —
    stay exactly the base workload's grid.  Empty = (lo, hi)."""

    lo: float
    hi: float
    #: neighbor() perturbation scale, as a fraction of the encoded range
    perturb: float = 0.2
    anchors: tuple = ()

    is_continuous = True

    def __post_init__(self):
        assert self.hi > self.lo
        assert all(self.contains(a) for a in self.anchors)

    def sample(self, rng):
        return float(rng.uniform(self.lo, self.hi))

    def contains(self, value):
        return self.lo <= value <= self.hi

    def encode(self, value):
        return (float(value) - self.lo) / (self.hi - self.lo)

    def decode(self, u):
        v = self.lo + (self.hi - self.lo) * min(max(float(u), 0.0), 1.0)
        return float(min(max(v, self.lo), self.hi))   # FP overshoot clamp

    def anchor_values(self):
        return self.anchors or (self.lo, self.hi)

    def neighbor(self, value, rng):
        u = self.encode(value) + self.perturb * float(rng.normal())
        return self.decode(u)


@dataclasses.dataclass(frozen=True)
class LogUniform(Uniform):
    """Continuous interval sampled/encoded on a log scale (learning rates:
    uniform in log-space, so 1e-3..1e-1 doesn't collapse onto the top)."""

    def __post_init__(self):
        assert 0 < self.lo < self.hi
        assert all(self.contains(a) for a in self.anchors)

    def sample(self, rng):
        v = math.exp(rng.uniform(math.log(self.lo), math.log(self.hi)))
        return float(min(max(v, self.lo), self.hi))

    def encode(self, value):
        return ((math.log(float(value)) - math.log(self.lo))
                / (math.log(self.hi) - math.log(self.lo)))

    def decode(self, u):
        u = min(max(float(u), 0.0), 1.0)
        v = math.exp(math.log(self.lo)
                     + u * (math.log(self.hi) - math.log(self.lo)))
        return float(min(max(v, self.lo), self.hi))   # FP overshoot clamp


@dataclasses.dataclass(frozen=True)
class IntUniform(Uniform):
    """Integer interval; encode/decode round-trip through the int lattice."""

    def __post_init__(self):
        assert self.hi > self.lo
        assert float(self.lo).is_integer() and float(self.hi).is_integer()
        assert all(self.contains(a) for a in self.anchors)

    def sample(self, rng):
        return int(rng.integers(int(self.lo), int(self.hi) + 1))

    def contains(self, value):
        return (self.lo <= value <= self.hi
                and float(value).is_integer())

    def decode(self, u):
        v = self.lo + (self.hi - self.lo) * min(max(float(u), 0.0), 1.0)
        return int(min(max(round(v), self.lo), self.hi))

    def anchor_values(self):
        return self.anchors or (int(self.lo), int(self.hi))

    def neighbor(self, value, rng):
        v = self.decode(self.encode(value) + self.perturb * float(rng.normal()))
        if v == value:             # a too-small move must still *move*
            v = value + (1 if value < self.hi else -1)
        return int(v)


#: what ``SearchSpace.from_legacy`` accepts per dim: an explicit Domain or
#: the legacy tuple-of-values shorthand (mapped to Ordinal)
DomainLike = Union[Domain, Sequence]


def as_domain(values: DomainLike) -> Domain:
    return values if isinstance(values, Domain) else Ordinal(tuple(values))


# ---------------------------------------------------------------------------
# config hashing
# ---------------------------------------------------------------------------


def _canon(value) -> str:
    """Canonical, process-independent text form of one HP value."""
    if isinstance(value, bool):
        return f"b:{value}"
    if isinstance(value, (int, np.integer)):
        return f"i:{int(value)}"
    if isinstance(value, (float, np.floating)):
        f = float(value)
        return _canon(int(f)) if f.is_integer() else f"f:{f.hex()}"
    return f"s:{value}"


def config_hash(hp: Dict[str, object]) -> int:
    """64-bit stable hash of a config dict (key-order independent)."""
    blob = "|".join(f"{k}={_canon(v)}"
                    for k, v in sorted(hp.items())).encode()
    return int.from_bytes(hashlib.blake2b(blob, digest_size=8).digest(),
                          "big")


# ---------------------------------------------------------------------------
# the space
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """Ordered, named product of domains.

    ``dims`` is a tuple of ``(name, Domain)`` pairs; declaration order is
    the feature-column order and, for finite spaces, the grid enumeration
    order (itertools.product over per-dim values — byte-compatible with the
    legacy ``Workload.hp_grid()``)."""

    dims: Tuple[Tuple[str, Domain], ...]

    def __post_init__(self):
        object.__setattr__(self, "dims",
                           tuple((k, as_domain(d)) for k, d in self.dims))
        names = [k for k, _ in self.dims]
        assert len(set(names)) == len(names), f"duplicate dim names: {names}"

    @classmethod
    def from_legacy(cls, hp_space: Iterable) -> "SearchSpace":
        """Legacy ``Workload.hp_space`` (``(key, (values...))`` tuples,
        Domains allowed in the value slot) -> SearchSpace."""
        return cls(tuple((k, as_domain(v)) for k, v in hp_space))

    # -------------------------------------------------------------- shape
    @property
    def names(self) -> List[str]:
        return [k for k, _ in self.dims]

    def __len__(self) -> int:
        return len(self.dims)

    @property
    def is_finite(self) -> bool:
        return not any(d.is_continuous for _, d in self.dims)

    def domain(self, name: str) -> Domain:
        for k, d in self.dims:
            if k == name:
                return d
        raise KeyError(name)

    # --------------------------------------------------------- enumeration
    def grid(self) -> List[dict]:
        """Every config of a finite space, legacy product order."""
        if not self.is_finite:
            cont = [k for k, d in self.dims if d.is_continuous]
            raise ValueError(f"space has continuous dims {cont}; "
                             "grid() needs an all-finite space")
        keys = self.names
        vals = [d.values for _, d in self.dims]
        return [dict(zip(keys, combo)) for combo in itertools.product(*vals)]

    def grid_size(self) -> Optional[int]:
        if not self.is_finite:
            return None
        n = 1
        for _, d in self.dims:
            n *= len(d.values)
        return n

    def anchor_grid(self) -> List[dict]:
        """Corner configs of the anchor lattice, product order.  Equals
        ``grid()`` for finite spaces; continuous dims anchor on (lo, hi)."""
        keys = self.names
        vals = [d.anchor_values() for _, d in self.dims]
        return [dict(zip(keys, combo)) for combo in itertools.product(*vals)]

    def grid_index(self, hp: dict) -> Optional[int]:
        """Anchor-lattice product index of an on-lattice config, else None."""
        idx = 0
        for k, d in self.dims:
            anchors = d.anchor_values()
            try:
                j = anchors.index(hp[k])
            except ValueError:
                return None
            idx = idx * len(anchors) + j
        return idx

    # ------------------------------------------------------------ sampling
    def sample(self, rng: Union[int, np.random.Generator],
               n: Optional[int] = None) -> Union[dict, List[dict]]:
        """``n`` seeded configs (one per call order: dims in declared order,
        configs consecutively — batch == loop).  ``n=None`` -> one config."""
        if not isinstance(rng, np.random.Generator):
            rng = np.random.default_rng(rng)
        single = n is None
        out = [{k: d.sample(rng) for k, d in self.dims}
               for _ in range(1 if single else n)]
        return out[0] if single else out

    #: consecutive duplicate draws ``sample_distinct`` tolerates before
    #: concluding a continuous-typed space is effectively exhausted (a pure
    #: ``IntUniform(0, 1)`` product holds only a handful of configs)
    MAX_DUP_MISSES = 64

    def sample_distinct(self, rng: Union[int, np.random.Generator],
                        n: int, seen: Optional[set] = None,
                        max_misses: Optional[int] = None) -> List[dict]:
        """Up to ``n`` configs with pairwise-distinct config hashes, also
        distinct from ``seen`` (mutated in place with the accepted hashes
        when supplied).  Gives up — returning fewer configs — after
        ``max_misses`` consecutive duplicate draws, so tiny
        continuous-typed spaces terminate instead of spinning.  Identical
        draw stream to ``sample`` while no duplicates occur."""
        if not isinstance(rng, np.random.Generator):
            rng = np.random.default_rng(rng)
        if max_misses is None:
            max_misses = self.MAX_DUP_MISSES
        seen = set() if seen is None else seen
        out: List[dict] = []
        misses = 0
        while len(out) < n and misses < max_misses:
            hp = self.sample(rng)
            h = self.config_hash(hp)
            if h in seen:
                misses += 1
                continue
            misses = 0
            seen.add(h)
            out.append(hp)
        return out

    def neighbor(self, hp: dict, rng: np.random.Generator) -> dict:
        """Perturb one seeded-random dim to a nearby value (PBT explore)."""
        k, d = self.dims[int(rng.integers(len(self.dims)))]
        out = dict(hp)
        out[k] = d.neighbor(hp[k], rng)
        return out

    # ----------------------------------------------------- feature matrix
    def encode_one(self, hp: dict) -> np.ndarray:
        return np.array([d.encode(hp[k]) for k, d in self.dims], np.float64)

    def encode(self, configs: Sequence[dict]) -> np.ndarray:
        """(n, d) normalized feature matrix — the numpy/jax hot-path view."""
        if not len(configs):
            return np.zeros((0, len(self.dims)), np.float64)
        return np.stack([self.encode_one(hp) for hp in configs])

    def decode_one(self, u: np.ndarray) -> dict:
        return {k: d.decode(u[i]) for i, (k, d) in enumerate(self.dims)}

    def decode(self, U: np.ndarray) -> List[dict]:
        U = np.atleast_2d(np.asarray(U, np.float64))
        assert U.shape[1] == len(self.dims)
        return [self.decode_one(row) for row in U]

    # ------------------------------------------------------------ identity
    def config_hash(self, hp: dict) -> int:
        return config_hash({k: hp[k] for k, _ in self.dims})

    def config_key(self, hp: dict) -> str:
        """Short stable identity fragment for trial keys off the grid."""
        return f"{self.config_hash(hp):016x}"[:12]
