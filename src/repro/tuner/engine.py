"""Policy-free transient-resource execution engine (SpotTune Algorithm 1's
mechanics, with the search policy factored out).

The engine owns everything the paper's orchestrator did *except* the decisions
about trial budgets and early stopping:

  * cost-aware deployment of waiting trials via the Provisioner (Eq. 2
    argmin), with VM-startup + checkpoint-restore latency charged before
    compute resumes;
  * revocation notices (checkpoint on notice, rollback on the revocation,
    first-hour refund accounting, requeue);
  * proactive 1-hour rotation (fresh market decision + a new refund window);
  * flag-gated straggler re-placement (beyond-paper, off by default).

Policy arrives through the event stream: every lifecycle transition is
narrated as a typed event (``repro.tuner.events``) to a ``Scheduler``, whose
``Decision``s the engine applies at exactly the points the legacy loop
evaluated its hardcoded conditions — so a scheduler that reproduces the
legacy conditions reproduces the legacy run bit-for-bit (seeded RNG draws
included).  ``PAUSE`` parks a trial on its checkpoint without redeploying it;
``take_promotions`` / ``resume`` bring parked trials back.

The tick discipline (one pass per ``tick_s`` of simulated time, trials
processed in activation order, waiting trials deployed at tick end) is kept
verbatim from the paper's Algorithm 1 SLEEP loop — but by default the engine
does not *step* every tick.  Between two consecutive lifecycle boundaries
(deployment becoming ready, revocation notice, the revocation itself, the
1-hour rotation, the next ``val_every`` metric crossing, reaching the target
step count, the horizon guard) a running trial's per-tick work is closed-form:
steps grow linearly in simulated time and the per-tick EWMA perf-matrix
updates consume noise draws that are deterministic in ``(workload.seed,
int(t))``.  The event-driven fast path therefore jumps simulated time straight
to the earliest boundary (snapped to the tick grid) and replays the skipped
ticks as one vectorized fold (``_advance_window``), which is exactly
equivalent to ticking through them.  Schedulers that implement
``preview_metrics`` let the jump clear non-actionable metric crossings too
(``_preview_boundary``), and straggler mode jumps to the predicted
perf-matrix crossing (``_straggler_boundary``) instead of stepping every
tick.  ``EngineConfig(exact_ticks=True)`` keeps the legacy tick-for-tick
loop; ``repro.tuner.equivalence`` pins fast == exact (billing, finish
times, metric histories) across seeds.

``run_cooperative`` is the generator form of the loop: it suspends at each
deploy point with a ``ProvisionBatch`` whose candidate bids are already
drawn, so a sweep runner can interleave many engines and answer their
revocation predictions in one cross-replica vmapped forward.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import itertools
import math
import os
from typing import Dict, List, Optional

import numpy as np

from repro.backends.base import TrialBackend
from repro.core.market import (HOUR, InstanceType, SpotMarket, _RecRef,
                               acquire_batch_multi)
from repro.core.provisioner import Choice, PerfModel, Provisioner
from repro.core.trial import TrialSpec
from repro.tuner.events import (HourRotation, MetricReported, RevocationNotice,
                                TrialFinished, TrialRevoked, TrialStarted)
from repro.tuner.scheduler import CONTINUE, Decision, DecisionKind, Scheduler


class Status(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    PAUSED = "paused"
    FINISHED = "finished"


@dataclasses.dataclass
class TrialState:
    spec: TrialSpec
    target_steps: float
    steps: float = 0.0
    ckpt_steps: float = 0.0
    status: Status = Status.WAITING
    # live allocation as a ledger row handle plus hot-column mirrors (the
    # tick/boundary chains read these instead of chasing an object)
    alloc_row: int = -1
    a_inst: Optional[InstanceType] = None
    a_t_start: float = 0.0
    a_t_revoke: float = math.inf     # inf = never within horizon
    choice: Optional[Choice] = None
    ready_at: float = 0.0
    notice_handled: bool = False
    alloc_start_steps: float = 0.0
    metrics_steps: List[int] = dataclasses.field(default_factory=list)
    metrics_vals: List[float] = dataclasses.field(default_factory=list)
    free_steps: float = 0.0
    lost_steps: float = 0.0
    ckpt_seconds: float = 0.0
    restore_seconds: float = 0.0
    billed_cost: float = 0.0         # $ billed to this trial, net of refunds
    redeployments: int = 0
    stopped: bool = False            # a STOP decision was applied
    pause_requested: bool = False
    exclude: set = dataclasses.field(default_factory=set)
    finish_time: float = 0.0
    _next_val: int = 0
    _last_t: float = 0.0             # last tick replayed (fast path only)
    _next_k: int = 0                 # next boundary tick index (fast path)
    _spt: float = 0.0                # cached noise-free secs/step (fast path)
    # preview memo (fast path, ``preview_stable`` schedulers only): the
    # answer of the last ``_preview_boundary`` call, the metric-point index
    # it covered, and the allocation epoch it was computed under
    _pv_epoch: tuple = ()
    _pv_cov: int = -1
    _pv_ans: Optional[int] = None
    _ckpt_s: float = -1.0            # memoized checkpoint transfer seconds
    key: str = ""                    # spec.key, materialized (hot attribute)

    def __post_init__(self):
        self.key = self.spec.key

    @property
    def converged(self) -> bool:
        """Legacy alias: the paper's only STOP reason was metric plateau."""
        return self.stopped


def _exact_ticks_default() -> bool:
    """REPRO_EXACT_TICKS=1 forces the legacy tick loop process-wide — the
    lever benchmarks/run.py --exact uses to measure the fast-path speedup."""
    return os.environ.get("REPRO_EXACT_TICKS", "0") not in ("", "0")


@dataclasses.dataclass
class EngineConfig:
    tick_s: float = 10.0
    deploy_delay_s: float = 60.0       # VM/slice startup
    ckpt_bandwidth_bps: float = 120e6  # object-store write speed (fig12 knob)
    notice_s: float = 120.0
    straggler_factor: float = 0.0      # 0 = off (paper); >1 enables mitigation
    max_sim_s: float = 10 * 24 * 3600.0
    seed: int = 0
    # time-windowed deploy batching: trials turning WAITING within
    # ``deploy_window_s`` of the first one are held and serviced together,
    # so cross-replica RevPred forwards see fatter batches.  0 (default)
    # deploys at the same tick the trial turns WAITING — the paper's (and
    # the equivalence-pinned) behavior.
    deploy_window_s: float = 0.0
    # False (default): event-driven boundary jumping; True: the legacy
    # tick-for-tick Algorithm 1 loop (the two are equivalence-pinned)
    exact_ticks: bool = dataclasses.field(default_factory=_exact_ticks_default)


def build_engine(market: SpotMarket, backend: TrialBackend, revpred,
                 seed: int = 0, **engine_kw) -> "ExecutionEngine":
    """Standard construction: fresh perf matrix + Eq.-2 provisioner around a
    market/backend pair.  Every driver (examples, benchmarks, tests, the
    legacy shim) wants exactly this wiring.  An engine is cheap to build —
    all heavyweight state (traces, indices, curves, jit caches) lives in
    shared pure memos — and fully replica-local: the only RNG it consumes
    is the provisioner's own seeded stream."""
    prov = Provisioner(market, revpred, PerfModel(market.pool), seed=seed)
    return ExecutionEngine(market, backend, prov,
                           EngineConfig(seed=seed, **engine_kw))


@dataclasses.dataclass
class ProvisionBatch:
    """A suspended deploy point of ``ExecutionEngine.run_cooperative``.

    ``items`` holds ``(trial_state, candidates)`` for every trial deploying
    at this tick, candidate bids already drawn (RNG order is fixed before
    the suspension).  The driver must fill ``responses`` — one p(revoke)
    list per item, aligned with its candidates — before resuming the
    generator; ``service_local`` answers with the engine's own predictor,
    reproducing the non-cooperative path bit-for-bit.  A sweep runner
    instead stacks the candidates of many suspended replicas into one
    vmapped RevPred forward."""

    engine: "ExecutionEngine"
    t: float
    items: List[tuple]
    responses: Optional[List[list]] = None

    def service_local(self) -> None:
        prov = self.engine.prov
        self.responses = [prov.predict_candidates(self.t, cands)
                          for _, cands in self.items]


class ExecutionEngine:
    """Runs trials on the transient market; consults a Scheduler for policy."""

    def __init__(self, market: SpotMarket, backend: TrialBackend,
                 provisioner: Provisioner, config: Optional[EngineConfig] = None):
        self.market = market
        self.backend = backend
        self.prov = provisioner
        self.cfg = config or EngineConfig()
        self.scheduler: Scheduler = Scheduler()
        self._drain_promos = False
        self._has_preview = False
        # backends that override the protocol's snapshot/restore no-ops get
        # the real lifecycle calls; for the sim (and legacy duck-typed
        # backends) the checkpoint hot path stays exactly the legacy
        # assignment.  Same type-level gating pattern as bind()'s.
        bt = type(backend)
        self._backend_snapshots = (
            getattr(bt, "snapshot", TrialBackend.snapshot)
            is not TrialBackend.snapshot)
        self._backend_restores = (
            getattr(bt, "restore", TrialBackend.restore)
            is not TrialBackend.restore)
        self._ckpt_time_fn = getattr(backend, "checkpoint_time", None)
        self.states: List[TrialState] = []
        self._by_key: Dict[str, TrialState] = {}
        self._active: List[TrialState] = []
        self._ledger = market.ledger
        self._events: List[tuple] = []
        self._ev_mat = 0         # prefix of _events already materialized
        self.t = 0.0
        # fast path: min-heap of (tick index, seq, trial) boundary entries
        # with lazy invalidation (stale when trial._next_k moved on)
        self._heap: List[tuple] = []
        self._seq = itertools.count()
        self._pending_deploy = False
        self._preview_stable = False
        self._table_events: frozenset = frozenset()
        self._has_table = False
        self._started_inert = False
        self._flush_k: Optional[int] = None   # armed deploy-window flush tick

    @property
    def events(self) -> List[tuple]:
        """Event log with deferred billing records materialized on read.

        Releases append a ``_RecRef`` row handle instead of building the
        record dict in the hot loop; the first read of the log resolves the
        new suffix in place (a materialized prefix is never re-resolved, so
        repeated reads stay O(new events))."""
        ev = self._events
        j = self._ev_mat
        n = len(ev)
        while j < n:
            e = ev[j]
            p = e[-1]
            if type(p) is _RecRef:
                ev[j] = e[:-1] + (p.record(),)
            j += 1
        self._ev_mat = n
        return ev

    # ------------------------------------------------------------- trials
    def bind(self, scheduler: Scheduler) -> None:
        self.scheduler = scheduler
        # schedulers that never promote asynchronously (the base no-op is
        # not overridden) skip the per-event promotion drain entirely
        self._drain_promos = (type(scheduler).take_promotions
                              is not Scheduler.take_promotions)
        # schedulers that can preview metric trajectories let the fast path
        # jump over non-actionable crossings instead of visiting each one
        self._has_preview = (type(scheduler).preview_metrics
                             is not Scheduler.preview_metrics)
        # schedulers declaring ``preview_stable`` promise their preview
        # answer depends only on the trial's combined (history + future)
        # metric sequence — which is invariant within one allocation — so
        # repeat previews can be served from the trial's memo
        self._preview_stable = bool(getattr(scheduler, "preview_stable",
                                            False))
        # schedulers exposing per-grid-index stop verdicts let the preview
        # skip trajectory materialization entirely (see _preview_boundary)
        self._preview_fast = getattr(scheduler, "preview_stop_grid", None)
        # batched decision-table capability (see Scheduler.decision_table):
        # only the two batchable event classes are honored — anything wider
        # keeps the scalar chain.  A table scheduler declares every class
        # outside table_events inert, which licenses skipping those
        # dispatches entirely (TrialStarted below; the SoA stepper skips the
        # lifecycle narration events the same way).
        self._table_events = getattr(scheduler, "table_events", frozenset())
        self._has_table = (
            getattr(type(scheduler), "decision_table", None) is not None
            and self._table_events <= {MetricReported, TrialRevoked})
        self._started_inert = (self._has_table
                               and TrialStarted not in self._table_events)

    def add_trial(self, spec: TrialSpec, target_steps: float) -> TrialState:
        assert spec.key not in self._by_key, f"duplicate trial key {spec.key}"
        st = TrialState(spec, target_steps=target_steps)
        self.states.append(st)
        self._by_key[spec.key] = st
        self._active.append(st)
        return st

    def views(self) -> List[TrialState]:
        return list(self.states)

    def resume(self, promotions: Dict[str, float]) -> None:
        """Resume trials with new budgets; the dict order is the activation
        (and hence deployment / RNG-consumption) order."""
        self._active = []
        for key, target in promotions.items():
            st = self._by_key[key]
            st.target_steps = target
            st.status = Status.WAITING
            self._active.append(st)

    # ------------------------------------------------------------- helpers
    def _ckpt_time(self, st: TrialState) -> float:
        # checkpoint bytes/time come from the backend: the default protocol
        # implementation prices model_bytes at the engine's bandwidth knob
        # (the legacy arithmetic, bit-exact); a training backend answers
        # from its object store's measured transfer model
        if self._ckpt_time_fn is not None:
            return self._ckpt_time_fn(st.spec, self.cfg.ckpt_bandwidth_bps)
        v = st._ckpt_s          # model size and bandwidth are fixed per trial
        if v < 0.0:
            v = st._ckpt_s = (self.backend.model_bytes(st.spec)
                              / self.cfg.ckpt_bandwidth_bps)
        return v

    def _checkpoint(self, st: TrialState, deadline_s: Optional[float] = None):
        """Persist trial state.  ``deadline_s`` is the transfer budget the
        snapshot must fit (the revocation-notice window); every other
        checkpoint event — hour rotation, pause, plateau stop, finish —
        has no deadline, so oversized models still persist there."""
        if self._backend_snapshots:
            # real snapshot: the backend persists actual training state and
            # answers with the step that is durable (the deadline gate may
            # pin it to an older snapshot for oversized models)
            st.ckpt_steps = self.backend.snapshot(
                st.spec, st.steps,
                float("inf") if deadline_s is None else deadline_s)
        else:
            st.ckpt_steps = st.steps
        st.ckpt_seconds += self._ckpt_time(st)

    def _release(self, st: TrialState, revoked: bool) -> None:
        row = st.alloc_row
        cost, refund = self._ledger.release_row(row, self.t, revoked)
        steps_this_alloc = st.ckpt_steps - st.alloc_start_steps
        st.billed_cost += cost - refund
        if refund > 0:
            st.free_steps += max(steps_this_alloc, 0.0)
        self._events.append((self.t, "release", st.spec.key,
                             _RecRef(self._ledger, row)))
        st.alloc_row = -1
        st.a_inst = None
        st.a_t_revoke = math.inf
        st.choice = None
        st.notice_handled = False

    def _deploy_chosen(self, st: TrialState, choice: Choice):
        """Complete a deployment whose Eq.-2 choice is already made."""
        row, t_rev = self._ledger.acquire_row(choice.inst, choice.max_price,
                                              self.t)
        self._deploy_row(st, choice, row, t_rev)

    def _deploy_row(self, st: TrialState, choice: Choice, row: int,
                    t_rev: float):
        """Finish a deployment whose ledger row was already acquired (the
        batched deploy paths answer a whole burst's crossing searches in
        one segmented scan before handing rows out)."""
        if st.exclude:
            st.exclude = set()
        st.alloc_row = row
        st.a_inst = choice.inst
        st.a_t_start = self.t
        st.a_t_revoke = t_rev
        st.choice = choice
        restore = self._ckpt_time(st) if st.steps > 0 else 0.0
        if self._backend_restores and st.steps > 0:
            # elastic re-shard path: rehydrate real training state from the
            # durable snapshot before compute resumes on the new slice
            self.backend.restore(st.spec, st.ckpt_steps)
        st.restore_seconds += restore
        st.ready_at = self.t + self.cfg.deploy_delay_s + restore
        st.alloc_start_steps = st.steps
        st.status = Status.RUNNING
        st.redeployments += 1
        st._last_t = self.t
        st._next_k = 0        # fresh allocation -> boundaries recomputed
        st._spt = self.backend.base_step_time(st.spec, choice.inst)
        self._events.append((self.t, "deploy", st.spec.key, choice.inst.name,
                            round(choice.max_price, 4), round(choice.p_revoke, 3)))
        if not self._started_inert:
            # table schedulers declare TrialStarted inert (no state change,
            # no staged promotions pending at this point), so the dispatch
            # — and its per-event promotion drain — is skippable
            self._dispatch(TrialStarted(self.t, st.key, choice.inst.name,
                                        choice.max_price, choice.p_revoke), st)

    def _advance(self, st: TrialState, dt: float) -> List[tuple]:
        """Simulate ``dt`` seconds of compute; returns new (step, value)
        metric points (already appended to the trial's history)."""
        inst = st.a_inst
        true_spt = self.backend.step_time(st.spec, inst)
        gained = dt / true_spt
        st.steps = min(st.steps + gained, st.target_steps)
        # observed seconds/step -> perf-matrix update (Algorithm 1 line 36)
        obs = self.backend.step_time(st.spec, inst, noisy_t=self.t)
        self.prov.perf.update(inst, st.spec, obs)
        # metric points crossed
        w = st.spec.workload
        new_points = []
        while (st._next_val + 1) * w.val_every <= st.steps:
            st._next_val += 1
            step = st._next_val * w.val_every
            val = self.backend.metric_at(st.spec, step)
            if val is not None:
                st.metrics_steps.append(step)
                st.metrics_vals.append(val)
                new_points.append((step, val))
        return new_points

    def _advance_window(self, st: TrialState) -> List[tuple]:
        """Fast-path advance: replay every skipped tick in ``(st._last_t,
        self.t]`` at once — one fused steps update, one vectorized EWMA fold
        over the deterministic noise draws, the same metric-crossing scan.

        Every crossed metric point is appended to the trial's history, but
        only the points the exact loop would first observe at the *final*
        tick of the window are returned for dispatch.  Without a previewing
        scheduler the two sets coincide (each crossing is its own boundary);
        with one, the interior points are exactly those the scheduler
        previewed as non-actionable — appending them silently is the whole
        point of the jump."""
        tick_s = self.cfg.tick_s
        t = self.t
        start = st.ready_at if st.ready_at > st._last_t else st._last_t
        st._last_t = t
        k0 = math.floor(start / tick_s) + 1       # first tick with dt > 0
        k1 = round(t / tick_s)
        if k1 < k0:
            return []                             # still inside deploy/restore
        inst = st.a_inst
        steps0 = st.steps
        st.steps = min(steps0 + (t - start) / st._spt, st.target_steps)
        obs = self.backend.noisy_step_times(st.spec, inst, k0, k1, tick_s,
                                            base=st._spt)
        self.prov.perf.update_many(inst, st.spec, obs)
        # steps as of the previous tick — what an every-tick scan had seen
        lim = (k1 - 1) * tick_s
        s_prev = steps0 if lim <= start else min(
            steps0 + (lim - start) / st._spt, st.target_steps)
        # metric points crossed (identical to the per-tick scan)
        w = st.spec.workload
        new_points = []
        while (st._next_val + 1) * w.val_every <= st.steps:
            st._next_val += 1
            step = st._next_val * w.val_every
            val = self.backend.metric_at(st.spec, step)
            if val is not None:
                st.metrics_steps.append(step)
                st.metrics_vals.append(val)
                if step > s_prev:
                    new_points.append((step, val))
        return new_points

    # ------------------------------------------------------------ decisions
    def _dispatch(self, event, st: TrialState) -> Decision:
        d = self.scheduler.on_event(event, st)
        if d is None:
            d = CONTINUE
        else:
            k = d.kind
            if k is DecisionKind.STOP:
                st.stopped = True
            elif k is DecisionKind.PAUSE:
                st.pause_requested = True
            elif k is DecisionKind.PROMOTE:
                st.target_steps = d.target_steps
        if self._drain_promos:
            promos = self.scheduler.take_promotions()
            if promos:
                for key, target in promos.items():
                    self._promote(key, target)
        return d

    def _promote(self, key: str, target: float):
        st = self._by_key[key]
        st.target_steps = target
        st._next_k = 0        # budget changed -> boundaries recomputed
        self._pending_deploy = True   # wake the fast path at the next tick
        if st.status in (Status.PAUSED, Status.FINISHED):
            st.status = Status.WAITING
        if st not in self._active:
            self._active.append(st)

    def _gate_deploys(self, waiting: List[TrialState]) -> List[TrialState]:
        """Δt deploy batching: hold WAITING trials until the window closes.

        On the first waiting trial the flush tick is armed ``deploy_window_s``
        ahead (snapped to the grid like every boundary); until it arrives the
        trials stay WAITING and accumulate, then the whole batch deploys in
        one suspension.  ``deploy_window_s == 0`` never gates."""
        cfg = self.cfg
        if not waiting or cfg.deploy_window_s <= 0.0:
            return waiting
        k_now = round(self.t / cfg.tick_s)
        if self._flush_k is None:
            k = math.ceil((self.t + cfg.deploy_window_s) / cfg.tick_s - 1e-7)
            self._flush_k = k if k > k_now else k_now
        if k_now < self._flush_k:
            return []
        self._flush_k = None
        return waiting

    def _park(self, st: TrialState):
        """Apply a PAUSE that coincides with an engine-forced release (the
        trial is already checkpointed and off its allocation)."""
        st.pause_requested = False
        st.status = Status.PAUSED
        self._events.append((self.t, "pause", st.spec.key))

    # ----------------------------------------------------------- main loop
    def run_until_idle(self):
        """Run until no trial is running or waiting (paused trials park;
        promotions delivered mid-run re-activate them).

        ``exact_ticks=True`` visits every ``tick_s`` of simulated time (the
        legacy Algorithm 1 SLEEP loop); the default fast path processes the
        same ticks a boundary falls on and jumps over the rest."""
        for req in self.run_cooperative():
            req.service_local()

    def run_cooperative(self):
        """Generator form of ``run_until_idle``: suspends at every deploy
        point with a ``ProvisionBatch`` the driver must answer before
        resuming.  This is what makes one engine step-interleavable with
        others — a sweep runner drives many replicas' generators and
        services their suspended deploys in one cross-replica batch.
        Serviced locally (``run_until_idle``) it is bit-identical to the
        pre-generator loop: candidate RNG draws happen before suspension in
        trial order, and deployments complete in the same order at the same
        tick."""
        cfg = self.cfg
        exact = cfg.exact_ticks
        while True:
            runnable = [s for s in self._active
                        if s.status in (Status.RUNNING, Status.WAITING)]
            if not runnable:
                return
            if self.t > cfg.max_sim_s or self.t >= self.market.horizon_s() - HOUR:
                raise RuntimeError("simulation horizon exhausted")
            touched = self._tick(runnable, exact)
            waiting = self._gate_deploys(
                [s for s in runnable if s.status == Status.WAITING])
            if waiting:
                batch = ProvisionBatch(self, self.t, [
                    (st, self.prov.candidates(self.t, st.spec,
                                              exclude=st.exclude or None))
                    for st in waiting])
                yield batch
                assert batch.responses is not None, "unserviced ProvisionBatch"
                # choices first (they read only the perf matrix and the
                # minute-memoized market rows, which deploys never touch),
                # then one batched acquire answers the burst's crossing
                # searches in a single segmented scan
                chosen = [(st, self.prov.choose(self.t, st.spec, cands, ps))
                          for (st, cands), ps in zip(batch.items,
                                                     batch.responses)]
                rows = acquire_batch_multi(
                    [(self.market, c.inst, c.max_price, self.t)
                     for _, c in chosen])
                for (st, choice), (row, t_rev) in zip(chosen, rows):
                    self._deploy_row(st, choice, row, t_rev)
                    touched.append(st)
            self.t = self.t + cfg.tick_s if exact else self._next_tick(touched)

    def _tick(self, runnable: List[TrialState], exact: bool) -> List[TrialState]:
        """One Algorithm-1 pass at ``self.t``: advance every running trial
        and apply the notice/revoke/finish/pause/rotate/straggler chain.
        Kept verbatim from the paper's loop — the two advance flavors are
        equivalence-pinned.  Waiting trials deploy at tick end, in the main
        loop (the deploy is the cooperative suspension point).  Returns the
        trials whose boundaries moved for rescheduling."""
        cfg = self.cfg
        k_now = round(self.t / cfg.tick_s)
        touched: List[TrialState] = []
        for st in runnable:
            if st.status != Status.RUNNING:
                continue
            if exact:
                run_from = max(st.ready_at, self.t - cfg.tick_s)
                dt = self.t - run_from
                new_points = self._advance(st, dt) if dt > 0 else []
            else:
                # a running trial only needs attention at its own boundaries:
                # nothing in its condition chain can fire before st._next_k,
                # and its skipped ticks replay exactly whenever it next folds
                if st._next_k > k_now:
                    continue
                touched.append(st)
                new_points = self._advance_window(st)
            for step, val in new_points:
                self._dispatch(MetricReported(self.t, st.key, step, val), st)

            trev = st.a_t_revoke        # inf = never, so no None checks
            # (1) revocation notice -> checkpoint (Algorithm 1 l.24-26).
            # The notice clamp max(t_start, trev - notice_s) leaves this
            # condition unchanged: t >= t_start always holds while running.
            if not st.notice_handled and self.t >= trev - cfg.notice_s:
                self._checkpoint(st, deadline_s=cfg.notice_s)
                st.notice_handled = True
                self._events.append((self.t, "notice", st.spec.key))
                self._dispatch(RevocationNotice(self.t, st.key, trev), st)
            # revocation fires
            if self.t >= trev:
                lost = st.steps - st.ckpt_steps
                st.lost_steps += lost
                st.steps = st.ckpt_steps      # roll back to checkpoint
                st._next_val = int(st.steps // st.spec.workload.val_every)
                n = int(st._next_val)
                st.metrics_steps = st.metrics_steps[:n]
                st.metrics_vals = st.metrics_vals[:n]
                self._release(st, revoked=True)
                st.status = Status.WAITING
                d = self._dispatch(
                    TrialRevoked(self.t, st.key, lost, st.ckpt_steps), st)
                if d.kind == DecisionKind.PAUSE or st.pause_requested:
                    self._park(st)  # free rung boundary (ASHA)
                continue
            # (2) finished: target reached or a STOP decision (l.27-30)
            if st.steps >= st.target_steps or st.stopped:
                st.pause_requested = False
                self._checkpoint(st)
                self._release(st, revoked=False)
                st.status = Status.FINISHED
                st.finish_time = self.t + self._ckpt_time(st)
                self._events.append((self.t, "finish", st.spec.key, st.steps))
                self._dispatch(
                    TrialFinished(self.t, st.key, st.steps, st.stopped), st)
                continue
            # scheduler-requested pause (rung boundary et al.)
            if st.pause_requested:
                self._checkpoint(st)
                self._release(st, revoked=False)
                self._park(st)
                continue
            # (3) one-hour proactive rotation (l.31-34)
            if self.t - st.a_t_start >= HOUR:
                self._checkpoint(st)
                held = self.t - st.a_t_start
                self._release(st, revoked=False)
                st.status = Status.WAITING
                self._events.append((self.t, "rotate", st.spec.key))
                d = self._dispatch(HourRotation(self.t, st.key, held), st)
                if d.kind == DecisionKind.PAUSE or st.pause_requested:
                    self._park(st)
                continue
            # beyond-paper: straggler re-placement
            if cfg.straggler_factor > 1.0 and self.t >= st.ready_at + 60:
                best_pred = min(self.prov.perf.get(i, st.spec)
                                for i in self.market.pool)
                obs = self.backend.step_time(st.spec, st.a_inst)
                if obs > cfg.straggler_factor * best_pred:
                    self._checkpoint(st)
                    st.exclude = {st.a_inst.name}
                    self._release(st, revoked=False)
                    st.status = Status.WAITING
                    self._events.append((self.t, "straggler", st.spec.key))
                    continue
        return touched

    def _next_tick(self, touched: List[TrialState]) -> float:
        """Earliest grid tick > ``self.t`` at which anything can happen.

        Per running trial the candidate boundaries are: the revocation notice,
        the revocation itself, the 1-hour rotation, reaching ``target_steps``
        (compute progresses at the deterministic noise-free step time measured
        from the trial's last replayed tick, so step boundaries are
        closed-form), metric crossings, and — in straggler mode — the first
        tick the perf-matrix comparison can fire (predicted by replaying the
        EWMA fold ahead, see ``_straggler_boundary``).  A previewing
        scheduler turns "every metric crossing" into "the first crossing it
        would act on" (``_preview_boundary``); without a preview each
        crossing stays its own boundary.  Boundaries are recomputed only for
        trials this tick touched and kept in a lazily invalidated min-heap,
        so a jump costs O(touched) instead of O(active).  Trials promoted
        mid-tick deploy at the next tick, like the legacy loop.  The jump
        never overshoots the horizon guards the main loop raises on."""
        cfg = self.cfg
        tick_s = cfg.tick_s
        k_now = round(self.t / tick_s)
        straggler = cfg.straggler_factor > 1.0
        heap = self._heap
        for st in touched:
            if st.status != Status.RUNNING:
                continue
            cand = st.a_t_start + HOUR                    # 1-hour rotation
            trev = st.a_t_revoke
            if trev < math.inf:
                # the notice boundary is clamped to the allocation start so
                # an over-price acquire never schedules a past-time event
                b = trev if st.notice_handled \
                    else max(st.a_t_start, trev - cfg.notice_s)
                if b < cand:
                    cand = b
            spt = st._spt
            start = st.ready_at if st.ready_at > st._last_t else st._last_t
            b = start + (st.target_steps - st.steps) * spt    # finish
            if b < cand:
                cand = b
            if not self._has_preview:
                w = st.spec.workload
                nstep = (st._next_val + 1) * w.val_every
                if nstep <= st.target_steps:              # next metric point
                    b = start + (nstep - st.steps) * spt
                    if b < cand:
                        cand = b
            # snap up to the grid; the 1e-7 slack only ever lands us one tick
            # early, where the (unchanged) condition chain simply re-arms
            k = math.ceil(cand / tick_s - 1e-7)
            if k <= k_now:
                k = k_now + 1
            if self._has_preview:
                k_act = self._preview_boundary(st, start, spt, k_now, k)
                if k_act is not None and k_act < k:
                    k = k_act
            if straggler:
                k_strag = self._straggler_boundary(st, start, k_now, k)
                if k_strag is not None and k_strag < k:
                    k = k_strag
            st._next_k = k
            heapq.heappush(heap, (k, next(self._seq), st))
        if self._pending_deploy:
            # a trial turned WAITING mid-tick (async promotion): deploy next
            # tick, exactly like the legacy loop
            self._pending_deploy = False
            return (k_now + 1) * tick_s
        while heap:
            k, _, st = heap[0]
            if k > k_now and st._next_k == k and st.status == Status.RUNNING:
                break
            heapq.heappop(heap)      # stale: rescheduled, parked, or done
        flush = self._flush_k
        if not heap:
            # nothing running: jump to an armed deploy-window flush, else
            # advance one tick (the legacy idle step)
            k = flush if flush is not None and flush > k_now else k_now + 1
        else:
            k = heap[0][0]
            if flush is not None and flush < k:
                k = flush if flush > k_now else k_now + 1
        k_guard = min(math.floor(cfg.max_sim_s / tick_s) + 1,
                      math.ceil((self.market.horizon_s() - HOUR) / tick_s))
        if k > k_guard:
            k = k_guard if k_guard > k_now else k_now + 1
        return k * tick_s

    def _preview_boundary(self, st: TrialState, start: float, spt: float,
                          k_now: int, k_limit: int) -> Optional[int]:
        """First tick <= ``k_limit`` at which the scheduler would act on a
        metric crossing, per its ``preview_metrics`` answer; None = none.

        The crossings that would occur through the end of tick ``k_limit``
        are materialized (step, value, observation tick) and handed to the
        scheduler; points it declares non-actionable are later appended
        silently by ``_advance_window`` without a boundary visit.

        For ``preview_stable`` schedulers the answer is memoized per trial:
        within one allocation epoch (no redeploy/rollback, unchanged budget,
        not stopped) the combined history+future metric sequence — and the
        point→tick map — is invariant, so a repeat preview whose coverage a
        prior call already spanned returns the recorded answer without
        re-materializing the trajectory."""
        w = st.spec.workload
        tick_s = self.cfg.tick_s
        lo = st._next_val + 1
        steps_end = st.steps + (k_limit * tick_s - start) / spt
        if steps_end > st.target_steps:
            steps_end = st.target_steps
        hi = int(steps_end // w.val_every)
        if hi < lo:
            return None
        stable = self._preview_stable
        if stable:
            epoch = (st.redeployments, st.target_steps, st.stopped)
            if (st._pv_epoch == epoch and hi <= st._pv_cov
                    and (st._pv_ans is None or st._pv_ans > k_now)):
                return st._pv_ans
        metric_range = getattr(self.backend, "metric_range", None)
        fast = self._preview_fast
        if fast is not None and metric_range is not None:
            vals_f = metric_range(st.spec, lo, hi)
            if None not in vals_f:
                ans = self._preview_scan(st, fast(st, vals_f, lo, hi),
                                         start, spt, k_now, lo, hi)
                if stable:
                    st._pv_epoch = epoch
                    st._pv_cov = hi
                    st._pv_ans = ans
                return ans
        steps_f = np.arange(lo, hi + 1, dtype=np.int64) * w.val_every
        if metric_range is not None:
            vals_f = metric_range(st.spec, lo, hi)
        else:
            vals_f = [self.backend.metric_at(st.spec, int(s)) for s in steps_f]
        if any(v is None for v in vals_f):
            # unreported points never reach the scheduler on any path
            keep = [i for i, v in enumerate(vals_f) if v is not None]
            if not keep:
                return None
            steps_f = steps_f[keep]
            vals_f = [vals_f[i] for i in keep]
        # observation tick per point: same snap (and slack) as the boundary
        # grid, so the chosen tick is exactly where the crossing dispatches
        ticks_f = np.ceil(
            (start + (steps_f - st.steps) * spt) / tick_s - 1e-7).astype(np.int64)
        np.clip(ticks_f, k_now + 1, None, out=ticks_f)
        i = self.scheduler.preview_metrics(st, steps_f, vals_f, ticks_f)
        ans = None if i is None else int(ticks_f[int(i)])
        if stable:
            st._pv_epoch = epoch
            st._pv_cov = hi
            st._pv_ans = ans
        return ans

    def _preview_scan(self, st: TrialState, ok, start: float, spt: float,
                      k_now: int, lo: int, hi: int) -> Optional[int]:
        """First acting tick given ``ok`` — sorted *global* grid indices
        whose prefixes pass the stop check (None = nothing fires).  A
        decision dispatches at the *end* of its observation tick, so only
        tick-end indices matter: walk the (typically empty or tiny)
        candidate subset inside [lo, hi], resolving each candidate's tick
        end in O(1) with the same snap arithmetic the vectorized trajectory
        path uses — bit-identical answers, no per-point arrays."""
        if ok is None:
            return None
        i0 = int(np.searchsorted(ok, lo))
        i1 = int(np.searchsorted(ok, hi, side="right"))
        if i0 == i1:
            return None
        idxs = ok[i0:i1]
        tick_s = self.cfg.tick_s
        ve = st.spec.workload.val_every
        steps0 = st.steps
        pos, n_idx = 0, len(idxs)
        while pos < n_idx:
            g = int(idxs[pos])
            K = math.ceil((start + (g * ve - steps0) * spt) / tick_s - 1e-7)
            if K <= k_now:
                K = k_now + 1
            # largest grid index whose (unclipped) snap lands at or before K
            # == the end of g's observation tick; the closed-form guess is
            # corrected against the exact snap predicate
            e = int((((K + 1e-7) * tick_s - start) / spt + steps0) // ve)
            if e > hi:
                e = hi
            elif e < g:
                e = g
            while e > g and math.ceil(
                    (start + (e * ve - steps0) * spt) / tick_s - 1e-7) > K:
                e -= 1
            while e < hi and math.ceil(
                    (start + ((e + 1) * ve - steps0) * spt)
                    / tick_s - 1e-7) <= K:
                e += 1
            if e == g:
                return K
            j = int(np.searchsorted(idxs, e))
            if j < n_idx and idxs[j] == e:
                return K
            pos = j
        return None

    def _straggler_boundary(self, st: TrialState, start: float, k_now: int,
                            k_limit: int) -> Optional[int]:
        """First tick <= ``k_limit`` at which the straggler re-placement can
        fire, or None.  The comparison ``obs > f * min(M[:, trial])`` only
        moves through this trial's own EWMA entry — other pool entries are
        frozen while it runs here — and the upcoming observations are the
        deterministic jitter draws, so the fold is replayed ahead (same
        arithmetic as ``PerfModel.update_many``) to find the crossing tick
        instead of forcing single-tick stepping."""
        cfg = self.cfg
        tick_s = cfg.tick_s
        inst = st.a_inst
        obs = self.backend.step_time(st.spec, inst)
        k_elig = math.ceil((st.ready_at + 60) / tick_s - 1e-7)
        if k_elig <= k_now:
            k_elig = k_now + 1
        if k_elig > k_limit:
            return None
        perf = self.prov.perf
        other_min = math.inf
        for i in self.market.pool:
            if i.name != inst.name:
                m_i = perf.get(i, st.spec)
                if m_i < other_min:
                    other_min = m_i
        f = cfg.straggler_factor
        m = perf.get(inst, st.spec)
        first = not perf.observed(inst, st.spec)
        k0 = math.floor(start / tick_s) + 1       # first tick that updates M
        vals = None
        if k0 <= k_limit:
            vals = self.backend.noisy_step_times(st.spec, inst, k0, k_limit,
                                                 tick_s, base=st._spt)
        a_e = perf.ewma
        b_e = 1 - a_e
        for k in range(k_now + 1, k_limit + 1):
            if k >= k0:
                o = vals[k - k0]
                m = o if first else b_e * m + a_e * o
                first = False
            if k >= k_elig and obs > f * (other_min if other_min < m else m):
                return k
        return None


def preview_boundary_batch(items) -> List[Optional[int]]:
    """``_preview_boundary`` over a whole deploy burst at once.

    ``items`` is a list of ``(engine, st, start, spt, k_now, k_limit)``
    tuples — one per replica row recomputing its boundary after a round's
    deploys.  The scalar path pays two ``np.searchsorted`` calls *per row*
    (~22k per fig9 run) just to learn that the scheduler's candidate set has
    no entry inside the row's ``[lo, hi]`` coverage window, which is the
    overwhelmingly common outcome.  Here the per-row candidate grids are
    packed into one offset-partitioned array (row ``i`` shifted by
    ``i * 2**40``, far above any real grid index) so a single sorted-search
    pair answers the emptiness test for every row; only rows with actual
    candidates fall back to the scalar ``_preview_scan`` snap-walk.

    Memoization, coverage bookkeeping, and every answer are bit-identical
    to calling ``eng._preview_boundary`` per row (pinned by
    tests/test_service.py); rows without the fast scheduler path or a
    ``metric_range`` backend simply delegate to the scalar method.
    """
    n = len(items)
    out: List[Optional[int]] = [None] * n
    # rows that reached the searchsorted stage: (out idx, eng, st, ok,
    # start, spt, k_now, lo, hi, stable, epoch)
    pend = []
    for i, (eng, st, start, spt, k_now, k_limit) in enumerate(items):
        w = st.spec.workload
        tick_s = eng.cfg.tick_s
        lo = st._next_val + 1
        steps_end = st.steps + (k_limit * tick_s - start) / spt
        if steps_end > st.target_steps:
            steps_end = st.target_steps
        hi = int(steps_end // w.val_every)
        if hi < lo:
            continue                              # scalar: None, no memo
        stable = eng._preview_stable
        epoch = None
        if stable:
            epoch = (st.redeployments, st.target_steps, st.stopped)
            if (st._pv_epoch == epoch and hi <= st._pv_cov
                    and (st._pv_ans is None or st._pv_ans > k_now)):
                out[i] = st._pv_ans
                continue
        metric_range = getattr(eng.backend, "metric_range", None)
        fast = eng._preview_fast
        if fast is None or metric_range is None:
            out[i] = eng._preview_boundary(st, start, spt, k_now, k_limit)
            continue
        vals_f = metric_range(st.spec, lo, hi)
        if None in vals_f:
            out[i] = eng._preview_boundary(st, start, spt, k_now, k_limit)
            continue
        ok = fast(st, vals_f, lo, hi)
        if ok is None or not len(ok):
            if stable:
                st._pv_epoch = epoch
                st._pv_cov = hi
                st._pv_ans = None
            continue
        pend.append((i, eng, st, ok, start, spt, k_now, lo, hi,
                     stable, epoch))
    if pend:
        BIG = np.int64(1) << np.int64(40)         # > any grid index
        offs = np.arange(len(pend), dtype=np.int64) * BIG
        cat = np.concatenate(
            [p[3].astype(np.int64, copy=False) + off
             for p, off in zip(pend, offs)])
        los = np.fromiter((p[7] for p in pend), np.int64,
                          len(pend)) + offs
        his = np.fromiter((p[8] for p in pend), np.int64,
                          len(pend)) + offs
        i0s = np.searchsorted(cat, los)
        i1s = np.searchsorted(cat, his, side="right")
        for (i, eng, st, ok, start, spt, k_now, lo, hi, stable,
             epoch), i0, i1 in zip(pend, i0s, i1s):
            ans = None
            if i0 != i1:
                # a real candidate inside [lo, hi]: resolve its acting
                # tick with the scalar snap-walk (rare)
                ans = eng._preview_scan(st, ok, start, spt, k_now, lo, hi)
            out[i] = ans
            if stable:
                st._pv_epoch = epoch
                st._pv_cov = hi
                st._pv_ans = ans
    return out
