"""Pluggable tuner: transient-resource engine x search policy.

engine      policy-free execution engine (market, provisioning,
            checkpoint/restore, refunds) + EngineConfig, TrialState, Status
events      typed trial lifecycle events the engine emits
space       typed HP domains (Choice/Ordinal/Uniform/LogUniform/IntUniform)
            composing into SearchSpace: seeded sampling, [0,1]^d encode /
            decode, config hashing, grid enumeration as the finite case
scheduler   Scheduler/Searcher protocols, Decision vocabulary, TrialView
searchers   GridSearcher / RandomSearcher / ListSearcher + ASHAScheduler
spottune    the paper's theta + EarlyCurve top-mcnt policy as a Scheduler
policies    Hyperband brackets, PBT exploit/explore, TrimTuner cost-aware
            BO (ridge/grid) + its GP continuous relaxation (trimtuner-gp)
registry    name -> factory registry (sweeps, benchmarks, conformance
            tests) + supports_continuous space gating + describe() CLI
tuner       Tuner facade + RunResult
"""

# initialize repro.core before any tuner submodule: core's orchestrator shim
# from-imports repro.tuner.engine, so entering the cycle from this side
# (e.g. `python -m repro.tuner.registry`) must let core finish first —
# otherwise orchestrator sees a half-initialized engine module
import repro.core  # noqa: F401  (isort: skip)

from repro.tuner.engine import (EngineConfig, ExecutionEngine,  # noqa: F401
                                ProvisionBatch, Status, TrialState,
                                build_engine)
from repro.tuner.events import (HourRotation, MetricReported,  # noqa: F401
                                RevocationNotice, TrialEvent, TrialFinished,
                                TrialRevoked, TrialStarted)
from repro.tuner.scheduler import (CONTINUE, PAUSE, PROMOTE, STOP,  # noqa: F401
                                   Decision, DecisionKind, Scheduler, Searcher,
                                   TrialView)
from repro.tuner.policies import (HyperbandScheduler,  # noqa: F401
                                  PBTScheduler, PBTSearcher,
                                  TrimTunerGPSearcher, TrimTunerSearcher)
from repro.tuner.registry import (POLICY_DEFAULTS, SCHEDULERS,  # noqa: F401
                                  SEARCHERS, describe, make_scheduler,
                                  make_searcher, searcher_supports)
from repro.tuner.space import (Choice, Domain, IntUniform,  # noqa: F401
                               LogUniform, Ordinal, SearchSpace, Uniform,
                               config_hash)
from repro.tuner.searchers import (AdaptiveGridSearcher,  # noqa: F401
                                   ASHAScheduler, GridSearcher, ListSearcher,
                                   RandomSearcher)
from repro.tuner.spottune import (AdaptiveSpotTuneScheduler,  # noqa: F401
                                  SpotTuneScheduler)
from repro.tuner.tuner import FitRequest, RunResult, Tuner  # noqa: F401
