"""Pluggable tuner: transient-resource engine x search policy.

engine      policy-free execution engine (market, provisioning,
            checkpoint/restore, refunds) + EngineConfig, TrialState, Status
events      typed trial lifecycle events the engine emits
scheduler   Scheduler/Searcher protocols, Decision vocabulary, TrialView
searchers   GridSearcher / RandomSearcher / ListSearcher + ASHAScheduler
spottune    the paper's theta + EarlyCurve top-mcnt policy as a Scheduler
policies    Hyperband brackets, PBT exploit/explore, TrimTuner cost-aware BO
registry    name -> factory registry (sweeps, benchmarks, conformance tests)
tuner       Tuner facade + RunResult
"""

from repro.tuner.engine import (EngineConfig, ExecutionEngine,  # noqa: F401
                                ProvisionBatch, Status, TrialState,
                                build_engine)
from repro.tuner.events import (HourRotation, MetricReported,  # noqa: F401
                                RevocationNotice, TrialEvent, TrialFinished,
                                TrialRevoked, TrialStarted)
from repro.tuner.scheduler import (CONTINUE, PAUSE, PROMOTE, STOP,  # noqa: F401
                                   Decision, DecisionKind, Scheduler, Searcher,
                                   TrialView)
from repro.tuner.policies import (HyperbandScheduler,  # noqa: F401
                                  PBTScheduler, PBTSearcher,
                                  TrimTunerSearcher)
from repro.tuner.registry import (POLICY_DEFAULTS, SCHEDULERS,  # noqa: F401
                                  SEARCHERS, make_scheduler, make_searcher)
from repro.tuner.searchers import (AdaptiveGridSearcher,  # noqa: F401
                                   ASHAScheduler, GridSearcher, ListSearcher,
                                   RandomSearcher)
from repro.tuner.spottune import (AdaptiveSpotTuneScheduler,  # noqa: F401
                                  SpotTuneScheduler)
from repro.tuner.tuner import FitRequest, RunResult, Tuner  # noqa: F401
