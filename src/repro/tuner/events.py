"""Typed trial lifecycle events emitted by the execution engine.

The engine (``repro.tuner.engine.ExecutionEngine``) owns the transient-resource
mechanics — market, provisioning, checkpoint/restore, refunds — and narrates
everything that happens to a trial as a stream of these events.  A
``Scheduler`` consumes the stream and answers with ``Decision``s
(``repro.tuner.scheduler``); it never touches the market directly.

Every event carries the simulation time ``t`` (seconds) and the ``trial`` key
(``TrialSpec.key``).  Event-specific payloads:

  TrialStarted      a deployment succeeded: instance name, the bid (max price)
                    and the provisioner's revocation-probability estimate
  MetricReported    a validation-metric point was crossed (step, value).  The
                    engine appends ALL points crossed in one tick's advance to
                    the trial's history before dispatching any of them, so a
                    handler for step k sees a ``view.metrics_vals`` that may
                    already include later points from the same tick — decide
                    on the view's full history, not on "history up to k".
                    With a scheduler that implements ``preview_metrics``,
                    points it previewed as inert are appended to the history
                    *silently* (no event) — only the first actionable point
                    and its same-tick companions dispatch.  Schedulers must
                    therefore not rely on seeing every crossing; the history
                    on the view is always complete.
  RevocationNotice  the market delivered the advance notice; the engine has
                    already checkpointed (the paper's l.24-26 reaction)
  TrialRevoked      the revocation fired; the trial rolled back to its
                    checkpoint (``lost_steps`` of work discarded) and was
                    requeued.  A ``PAUSE`` decision parks it instead —
                    ASHA uses this: the forced checkpoint is a free rung
                    boundary.
  HourRotation      the engine voluntarily rotated the trial off its
                    allocation at the 1-hour billing boundary
  TrialFinished     the trial reached its target steps (or a ``STOP``
                    decision); it has checkpointed and released its allocation
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TrialEvent:
    """Base: simulation time + trial key."""

    t: float
    trial: str


@dataclasses.dataclass(frozen=True)
class TrialStarted(TrialEvent):
    inst: str
    max_price: float
    p_revoke: float


@dataclasses.dataclass(frozen=True)
class MetricReported(TrialEvent):
    step: int
    value: float


@dataclasses.dataclass(frozen=True)
class RevocationNotice(TrialEvent):
    t_revoke: float


@dataclasses.dataclass(frozen=True)
class TrialRevoked(TrialEvent):
    lost_steps: float
    ckpt_steps: float


@dataclasses.dataclass(frozen=True)
class HourRotation(TrialEvent):
    held_s: float


@dataclasses.dataclass(frozen=True)
class TrialFinished(TrialEvent):
    steps: float
    stopped: bool
