"""End-to-end driver: REAL JAX training under the SpotTune loop.

Hyper-parameter-tunes a reduced seed config (qwen1.5-0.5b by default) with
ACTUAL train steps on this machine, through the same engine/policy stack
the simulation uses — ``ScenarioSpec(backend="training")`` swaps the
synthetic ``SimTrialBackend`` for ``repro.backends.training``:

  * each trial is a ``repro.launch.train.Trainer`` (real forward/backward);
    ``SearchSpace`` configs bind to real knobs via ``TrainingBinding``
    (lr -> AdamW peak LR, dr/ds -> exponential decay, bs -> batch);
  * the simulated spot market supplies instance choices, revocations with
    the 2-minute notice, first-hour refunds, and billing; per-instance step
    time comes from the HLO/roofline cost model of the compiled train step;
  * on revocation the engine checkpoints through ``repro.checkpoint`` into
    a bandwidth-modelled object store (gated by ``fits_deadline``) and the
    next deploy restores the real optimizer state (elastic restart — the
    paper's core mechanism);
  * the search policy is any registered scheduler; the default is the
    paper's ``SpotTuneScheduler`` with EarlyCurve final-loss prediction
    fitted on the real validation-loss stream.

    PYTHONPATH=src python examples/e2e_hpt_train.py                 # ~1 min
    PYTHONPATH=src python examples/e2e_hpt_train.py --arch mamba2-130m
    PYTHONPATH=src python examples/e2e_hpt_train.py --scheduler pbt
"""

import argparse
import time

from repro.backends.training import TRAINING_ARCHS
from repro.sweep.runner import SweepRunner
from repro.sweep.spec import ScenarioSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=TRAINING_ARCHS)
    ap.add_argument("--scheduler", default="spottune")
    ap.add_argument("--theta", type=float, default=0.7)
    ap.add_argument("--market-seed", type=int, default=0)
    ap.add_argument("--days", type=float, default=2.0)
    args = ap.parse_args()

    spec = ScenarioSpec(workload=args.arch, market_seed=args.market_seed,
                        scheduler=args.scheduler, theta=args.theta,
                        backend="training", days=args.days)
    spec.validate()
    print(f"arch={args.arch} scheduler={args.scheduler} theta={args.theta} "
          f"market_seed={args.market_seed}")

    t0 = time.time()
    tuner = SweepRunner().prepare([spec])[0]
    backend = tuner.engine.backend
    res = tuner.run()
    wall = time.time() - t0

    print(f"\nbest (EarlyCurve-predicted): {res.predicted_rank[0]}  "
          f"true best: {res.true_rank[0]}  top-1 correct: {res.top1_correct}")
    print(f"virtual cost=${res.cost:.2f} (refunded ${res.refunded:.2f}), "
          f"JCT={res.jct/3600:.1f} h, redeployments={res.redeployments}")
    print(f"real checkpoints: {backend.snapshots} snapshots, "
          f"{backend.restores} restores "
          f"({backend.store.inner.bytes_written/1e6:.1f} MB written, "
          f"simulated transfer {backend.store.simulated_time:.1f}s)")
    for v in sorted(tuner.engine.views(), key=lambda v: v.key):
        host = backend.host_step_time(v.spec)
        last = v.metrics_vals[-1] if v.metrics_vals else float("nan")
        print(f"  {v.key}: steps={v.steps:.0f} loss={last:.4f} "
              f"host {host*1e3:.0f} ms/step")
    print(f"wall time {wall:.1f}s")


if __name__ == "__main__":
    main()
