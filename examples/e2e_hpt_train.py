"""End-to-end driver: REAL JAX training under the SpotTune loop.

Hyper-parameter-tunes a ~100M-param dense LM (a scaled-down qwen-family
config) over a small HP grid with ACTUAL train steps on this machine:

  * each trial is a repro.launch.train.Trainer (real forward/backward);
  * a simulated spot market supplies instance choices, revocations with the
    2-minute notice, first-hour refunds, and billing — instance speed maps
    real step time onto virtual market time via per-slice speed factors;
  * on revocation the trial checkpoints to the (throttled) object store and
    is re-deployed on the provisioner's next Eq.-2 pick, restoring from the
    checkpoint (elastic restart — the paper's core mechanism);
  * the *search policy* is the pluggable ``SpotTuneScheduler``
    (repro.tuner): each trial's theta-fraction budget comes from
    ``on_trial_added``, metric points are fed to it as ``MetricReported``
    events (a STOP answer = plateau early-shutdown), and the
    ``on_idle`` promotion round picks the top-mcnt trials to continue to
    completion from their checkpoints — the same scheduler object that
    drives the simulation engine, here driving real training.

    PYTHONPATH=src python examples/e2e_hpt_train.py --small       # ~2 min
    PYTHONPATH=src python examples/e2e_hpt_train.py               # ~100M params
"""

import argparse
import os
import tempfile

from repro.checkpoint import CheckpointManager, LocalObjectStore, ThrottledStore
from repro.checkpoint.checkpointer import tree_bytes
from repro.configs.base import ModelConfig
from repro.core.earlycurve import EarlyCurve
from repro.core.market import HOUR, SpotMarket
from repro.core.provisioner import PerfModel, Provisioner
from repro.core.revpred import OracleRevPred
from repro.core.trial import TrialSpec, Workload
from repro.launch.train import Trainer
from repro.optim.schedules import exponential_decay_schedule
from repro.tuner import (DecisionKind, MetricReported, SpotTuneScheduler,
                         TrialView)


def lm_100m():
    return ModelConfig(
        name="hpt-lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=10, d_ff=2560, vocab_size=32064,
        dtype="float32")


def lm_small():
    return ModelConfig(
        name="hpt-lm-small", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=384, vocab_size=1024, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--theta", type=float, default=0.7)
    ap.add_argument("--mcnt", type=int, default=1)
    args = ap.parse_args()

    cfg = lm_small() if args.small else lm_100m()
    batch, seq = (4, 64) if args.small else (4, 128)
    max_steps = args.steps or (60 if args.small else 300)
    val_every = max(2, max_steps // 30)
    hps = [
        {"lr": 3e-3, "dr": 1.0, "ds": max_steps},
        {"lr": 1e-3, "dr": 0.5, "ds": max_steps // 3},
        {"lr": 3e-4, "dr": 1.0, "ds": max_steps},
        {"lr": 1e-2, "dr": 0.3, "ds": max_steps // 3},
    ]
    from repro.models.model import count_params_analytic

    print(f"model: {cfg.name} ({count_params_analytic(cfg)/1e6:.1f}M params), "
          f"{len(hps)} HP settings, max_steps={max_steps}, theta={args.theta}")

    market = SpotMarket(days=12, seed=3)
    revpred = OracleRevPred(market)
    perf = PerfModel(market.pool)
    prov = Provisioner(market, revpred, perf, seed=0)
    workload = Workload("hpt-lm", (), max_steps, val_every, s0=1.0,
                        scale_exp=0.5, model_bytes=1.0)
    store = ThrottledStore(LocalObjectStore(
        os.path.join(tempfile.mkdtemp(prefix="spottune_s3_"), "bucket")),
        bandwidth_bps=134.22e6, latency_s=0.05, simulate=True)

    # the paper's policy, as a pluggable scheduler over real training
    sched = SpotTuneScheduler(theta=args.theta, mcnt=args.mcnt,
                              earlycurve=EarlyCurve(min_points=4), seed=0)

    # real seconds/step measured on THIS machine correspond to the 8-chip
    # reference slice; other slices scale virtual time by chips^0.5
    def speed_factor(inst):
        return (inst.chips / 8.0) ** 0.5

    t_virtual = 4 * HOUR  # market entry time
    trainers = {}
    views = []
    for i, hp in enumerate(hps):
        spec = TrialSpec(workload, hp, i)
        view = TrialView(spec, target_steps=sched.on_trial_added(spec))
        views.append(view)
        sched_stop = False

        schedfn = exponential_decay_schedule(hp["lr"], hp["dr"], hp["ds"])
        mgr = CheckpointManager(store, f"hp{i:02d}", save_interval_steps=10**9,
                                keep_n=2)
        tr = Trainer(cfg, batch=batch, seq=seq, seed=0, lr_schedule=schedfn,
                     ckpt=mgr, val_every=val_every)
        trainers[i] = tr
        # the trainer owns the metric history; the scheduler sees it live
        view.metrics_steps = tr.metrics_steps
        view.metrics_vals = tr.metrics_vals
        cost0 = market.billed
        t = t_virtual
        while tr.step < view.target_steps and not sched_stop:
            choice = prov.best_instance(t, spec)
            alloc = market.acquire(choice.inst, choice.max_price, t)
            t += 60.0 + (store.transfer_time(tree_bytes(tr.state))
                         if tr.step else 0.0)  # deploy + restore
            if tr.step:
                tr.restore()
                # restore() rebuilds the metric lists; re-alias the view
                view.metrics_steps = tr.metrics_steps
                view.metrics_vals = tr.metrics_vals
            # run until revocation notice / hour rotation / finish / STOP
            sf = speed_factor(choice.inst)
            while tr.step < view.target_steps:
                done = len(tr.metrics_vals)
                tr.run_steps(min(val_every, int(view.target_steps) - tr.step))
                t += tr.mean_step_time() * val_every / sf
                view.steps = tr.step
                perf.update(choice.inst, spec, tr.mean_step_time() / sf)
                for step, val in zip(tr.metrics_steps[done:],
                                     tr.metrics_vals[done:]):
                    d = sched.on_event(MetricReported(t, view.key, step, val),
                                       view)
                    if d.kind == DecisionKind.STOP:
                        sched_stop = view.stopped = True
                if sched_stop:
                    tr.save()
                    market.release(alloc, t, revoked=False)
                    print(f"  hp{i:02d}: plateau STOP at step {tr.step}")
                    break
                notice = market.notice_time(alloc)
                if notice is not None and t >= notice:
                    tr.save()                       # checkpoint on notice
                    t = alloc.t_revoke
                    market.release(alloc, t, revoked=True)
                    print(f"  hp{i:02d}: REVOKED {choice.inst.name} at step "
                          f"{tr.step} (checkpointed, refunded)")
                    break
                if t - alloc.t_start >= HOUR:       # 1-hour proactive rotate
                    tr.save()
                    market.release(alloc, t, revoked=False)
                    print(f"  hp{i:02d}: hour-rotation off {choice.inst.name} "
                          f"at step {tr.step}")
                    break
            else:
                tr.save()
                market.release(alloc, t, revoked=False)
        view.steps = tr.step
        print(f"  hp{i:02d} lr={hp['lr']:g} dr={hp['dr']:g}: "
              f"loss@{tr.step}={tr.metrics_vals[-1]:.4f} "
              f"virtual cost=${market.billed - cost0:.2f}")

    # phase 2: the scheduler predicts finals and promotes the top-mcnt
    promotions = sched.on_idle(views)
    preds = sched.predictions(views)
    ranked = sched.rank(views)
    print(f"\nEarlyCurve predictions: "
          f"{ {k: round(v, 4) for k, v in preds.items()} }")
    print(f"ranking: {ranked}; continuing top-{args.mcnt}: {list(promotions)}")
    for view in views:
        if view.key not in promotions:
            continue
        i = view.spec.idx
        tr = trainers[i]
        view.target_steps = promotions[view.key]
        tr.run_steps(int(view.target_steps) - tr.step)
        view.steps = tr.step
        print(f"  hp{i:02d} final loss@{tr.step}: {tr.metrics_vals[-1]:.4f}")

    print(f"\nTOTAL billed=${market.billed:.2f} refunded=${market.refunded:.2f} "
          f"(ckpt store wrote {store.inner.bytes_written/1e6:.1f} MB, "
          f"simulated transfer {store.simulated_time:.1f}s)")
    best = ranked[0]
    best_i = [v.spec.idx for v in views if v.key == best][0]
    print(f"selected model: hp{best_i:02d} {hps[best_i]}")


if __name__ == "__main__":
    main()
