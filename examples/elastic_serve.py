"""Elastic re-deployment + serving demo (the Algorithm-1 migration path on
real compute): train a tiny LM briefly, checkpoint it to the object store,
restore it ONTO A DIFFERENT MESH via per-leaf resharding, and serve batched
greedy generations from the migrated weights.

    PYTHONPATH=src python examples/elastic_serve.py
"""

import tempfile

import jax
import numpy as np

from repro.checkpoint import LocalObjectStore
from repro.configs.base import get_config
from repro.launch.elastic import ElasticTrial, slice_mesh, state_shardings
from repro.launch.serve import Server
from repro.launch.train import Trainer


def main():
    cfg = get_config("qwen1.5-0.5b", reduced=True)
    store = LocalObjectStore(tempfile.mkdtemp(prefix="spottune_elastic_"))

    print("== phase 1: train on 'slice A' ==")
    tr = Trainer(cfg, batch=4, seq=32, seed=0, val_every=5)
    tr.run_steps(30)
    print(f"   step={tr.step} loss={tr.metrics_vals[-1]:.4f}")

    trial = ElasticTrial(cfg, store, "trial0")
    trial.save(tr.step, tr.state)
    print("   checkpointed to object store")

    print("== phase 2: revocation! restore onto 'slice B' (different mesh) ==")
    mesh_b = slice_mesh()  # whatever devices this host exposes
    shapes = jax.eval_shape(lambda: tr.state)
    state_b, step = trial.restore_onto(mesh_b, shapes)
    print(f"   restored step {step} onto mesh {dict(mesh_b.shape)}")
    for leaf in jax.tree.leaves(state_b)[:1]:
        print(f"   example leaf sharding: {leaf.sharding}")

    print("== phase 3: serve from the migrated weights ==")
    server = Server(cfg, state_b["params"], max_len=96)
    rng = np.random.default_rng(0)
    prompts = {"tokens": jax.numpy.asarray(
        rng.integers(0, cfg.vocab_size, size=(4, 16), dtype=np.int32))}
    gen = server.generate(prompts, max_new_tokens=16)
    print(f"   generated {gen.shape} tokens; sample row: {np.asarray(gen[0])}")


if __name__ == "__main__":
    main()
