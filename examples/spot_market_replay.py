"""Spot-market tooling demo: synthesize (or replay) price traces, train the
three revocation predictors, and show Eq. 1/2 provisioning decisions.

    PYTHONPATH=src python examples/spot_market_replay.py [--csv path.csv]

With --csv, traces replay a Kaggle `aws-spot-pricing-market` style dump
(Timestamp, InstanceType, SpotPrice columns) instead of the synthesizer.
"""

import argparse

import numpy as np

from repro.core.market import DEFAULT_POOL, HOUR, SpotMarket, load_csv_traces
from repro.core.provisioner import PerfModel, Provisioner
from repro.core.revpred import RevPred, build_dataset, evaluate
from repro.core.trial import WORKLOADS, make_trials


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None)
    ap.add_argument("--days", type=float, default=6.0)
    args = ap.parse_args()

    traces = None
    if args.csv:
        with open(args.csv) as f:
            traces = load_csv_traces(f.read(), DEFAULT_POOL, int(args.days * 1440))
    market = SpotMarket(days=args.days, seed=5, traces=traces)

    print("=== market snapshot (t = 24h) ===")
    for inst in market.pool:
        p = market.price(inst, 24 * HOUR)
        print(f"  {inst.name:8s} od=${inst.od_price:6.2f}/h  spot=${p:6.2f}/h "
              f"({100 * p / inst.od_price:.0f}% of od)")

    print("\n=== training RevPred (per-market LSTM) + baselines ===")
    train_min = int((args.days - 2) * 1440)
    rng = np.random.default_rng(0)
    for kind in ("revpred", "tributary", "logreg"):
        rp = RevPred.train(market, train_min, kind=kind, epochs=2, stride=8)
        inst = market.pool[0]
        data = build_dataset(market.traces[inst.name], inst.od_price,
                             train_min, int(args.days * 1440) - 70, "random",
                             rng, stride=4)
        m = evaluate(rp.predictors[inst.name], data)
        print(f"  {kind:10s} heldout acc={m['accuracy']:.3f} f1={m['f1']:.3f}")
        if kind == "revpred":
            revpred = rp

    print("\n=== Eq. 2 provisioning decision at t = 36h ===")
    trial = make_trials(WORKLOADS[0])[0]
    prov = Provisioner(market, revpred, PerfModel(market.pool), seed=0)
    for inst in market.pool:
        mp = market.price(inst, 36 * HOUR) + 0.01 * inst.od_price
        p = revpred.predict(inst, 36 * HOUR, mp)
        scost = (prov.perf.get(inst, trial) * (1 - p)
                 * market.avg_price(inst, 36 * HOUR) / HOUR)
        print(f"  {inst.name:8s} p_revoke={p:.2f}  E[step cost]=${scost:.6f}")
    best = prov.best_instance(36 * HOUR, trial)
    print(f"  -> getBestInst: {best.inst.name} (max_price=${best.max_price:.3f}, "
          f"p={best.p_revoke:.2f})")


if __name__ == "__main__":
    main()
