"""Quickstart: SpotTune end-to-end in simulation, in under a minute on CPU.

Runs the paper's full loop on one workload (16 HP settings):
  synthetic spot market -> cost-aware provisioning (Eq. 2) -> Algorithm-1
  orchestration with revocation/checkpoint/refund -> EarlyCurve early
  shutdown at theta=0.7 -> top-3 continuation -> comparison against the two
  single-spot baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.market import SpotMarket
from repro.core.orchestrator import build_spottune, run_single_spot_baseline
from repro.core.revpred import OracleRevPred
from repro.core.trial import WORKLOADS, SimTrialBackend, make_trials


def main():
    workload = WORKLOADS[0]  # LoR benchmark (Table II analogue)
    trials = make_trials(workload)
    print(f"workload={workload.name}: {len(trials)} HP settings, "
          f"max_trial_steps={workload.max_trial_steps}")

    market = SpotMarket(days=12, seed=3)
    backend = SimTrialBackend(market.pool)
    orch = build_spottune(trials, market, backend, OracleRevPred(market),
                          theta=0.7, mcnt=3, seed=0)
    res = orch.run()
    print(f"\nSpotTune(theta=0.7):")
    print(f"  cost=${res.cost:.2f}  (+${res.refunded:.2f} refunded back)")
    print(f"  JCT={res.jct / 3600:.2f} h")
    print(f"  free steps (refunded allocations): {res.free_frac:.1%}")
    print(f"  checkpoint+restore overhead: {res.ckpt_frac:.1%} of JCT")
    print(f"  predicted best: {res.predicted_rank[0]}  true best: {res.true_rank[0]}")
    print(f"  top-3 contains true best: {res.top3_contains_best}")

    for label, pick in (("cheapest", min(market.pool, key=lambda i: i.od_price)),
                        ("fastest", max(market.pool, key=lambda i: i.chips))):
        m = SpotMarket(days=12, seed=3)
        r = run_single_spot_baseline(m, backend, trials, pick)
        print(f"\nSingle-Spot ({label}, {pick.name}): cost=${r.cost:.2f} "
              f"JCT={r.jct / 3600:.2f} h  "
              f"PCR ratio vs SpotTune: {r.pcr() / res.pcr():.2f}x")


if __name__ == "__main__":
    main()
