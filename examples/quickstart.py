"""Quickstart: SpotTune end-to-end in simulation, in under a minute on CPU.

Runs the paper's full loop on one workload (16 HP settings) through the
pluggable tuner API:
  synthetic spot market -> cost-aware provisioning (Eq. 2) -> policy-free
  execution engine with revocation/checkpoint/refund -> SpotTuneScheduler
  (EarlyCurve early shutdown at theta=0.7, top-3 continuation) -> comparison
  against the two single-spot baselines -> the same engine re-run under an
  ASHA scheduler to show the policy is swappable.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.market import SpotMarket
from repro.core.orchestrator import run_single_spot_baseline
from repro.core.revpred import OracleRevPred
from repro.core.trial import WORKLOADS, SimTrialBackend, make_trials
from repro.tuner import (ASHAScheduler, GridSearcher, SpotTuneScheduler,
                         Tuner, build_engine)


def fresh_engine(seed_market: int = 3, seed: int = 0):
    market = SpotMarket(days=12, seed=seed_market)
    backend = SimTrialBackend(market.pool)
    return build_engine(market, backend, OracleRevPred(market), seed=seed)


def main():
    workload = WORKLOADS[0]  # LoR benchmark (Table II analogue)
    print(f"workload={workload.name}: {len(workload.hp_grid())} HP settings, "
          f"max_trial_steps={workload.max_trial_steps}")

    engine = fresh_engine()
    tuner = Tuner(engine, SpotTuneScheduler(theta=0.7, mcnt=3),
                  GridSearcher(workload))
    res = tuner.run()
    print(f"\nSpotTune(theta=0.7):")
    print(f"  cost=${res.cost:.2f}  (+${res.refunded:.2f} refunded back)")
    print(f"  JCT={res.jct / 3600:.2f} h")
    print(f"  free steps (refunded allocations): {res.free_frac:.1%}")
    print(f"  checkpoint+restore overhead: {res.ckpt_frac:.1%} of JCT")
    print(f"  predicted best: {res.predicted_rank[0]}  true best: {res.true_rank[0]}")
    print(f"  top-3 contains true best: {res.top3_contains_best}")

    backend = engine.backend
    for label, pick in (("cheapest", min(engine.market.pool, key=lambda i: i.od_price)),
                        ("fastest", max(engine.market.pool, key=lambda i: i.chips))):
        m = SpotMarket(days=12, seed=3)
        r = run_single_spot_baseline(m, backend, make_trials(workload), pick)
        print(f"\nSingle-Spot ({label}, {pick.name}): cost=${r.cost:.2f} "
              f"JCT={r.jct / 3600:.2f} h  "
              f"PCR ratio vs SpotTune: {r.pcr() / res.pcr():.2f}x")

    # same engine mechanics, different policy: asynchronous successive halving
    asha = Tuner(fresh_engine(), ASHAScheduler(eta=2),
                 GridSearcher(workload)).run()
    print(f"\nASHA(eta=2) on the same engine: cost=${asha.cost:.2f} "
          f"JCT={asha.jct / 3600:.2f} h  best={asha.predicted_rank[0]}  "
          f"(grid ran {len([s for s in asha.per_trial_steps.values() if s >= workload.max_trial_steps])} "
          f"trials to full budget)")


if __name__ == "__main__":
    main()
